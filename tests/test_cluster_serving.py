"""Scale-out serving tier (DESIGN.md §11): DRHM router invariants + cluster
parity across modes/placements.

Router tests are pure host logic.  Replicated/stacked cluster tests run on
any device count (the vmapped lane step is device-agnostic).  Sharded-mode
and mesh-placement tests need the emulated 8-device mesh: they run directly
when ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` is set (the CI
multi-device leg), and tier-1 single-device runs exercise them through one
subprocess smoke instead.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import drhm
from repro.launch.gnn_serve import build_world
from repro.serve import ClusterServer, DRHMRouter, utilization_spread

N_LANES = 8
multi_device = pytest.mark.skipif(
    jax.device_count() < N_LANES,
    reason=f"needs {N_LANES} devices (the CI multi-device leg sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# Router invariants
# ---------------------------------------------------------------------------

def test_router_map_is_exact_balance_bijection():
    """Every epoch's bin→lane map gives each lane exactly n_bins/n_lanes
    bins — the DRHM bijectivity property carried up to routing."""
    r = DRHMRouter(N_LANES, n_bins=1024, seed=3)
    for _ in range(5):
        lane_map = r.lane_map()
        counts = np.bincount(lane_map, minlength=N_LANES)
        assert (counts == r.n_bins // N_LANES).all(), counts
        r.reseed()


def test_reseed_changes_the_map():
    r = DRHMRouter(N_LANES, n_bins=1024, seed=0)
    before = r.lane_map()
    gamma_before = r.gamma
    r.reseed()
    assert r.gamma != gamma_before
    after = r.lane_map()
    assert (before != after).mean() > 0.5     # most bins moved lanes

def test_route_gamma_is_odd_and_epoch_dependent():
    gs = {drhm.route_gamma(7, k) for k in range(32)}
    assert len(gs) == 32
    assert all(g % 2 == 1 for g in gs)


def test_routing_deterministic_and_in_range():
    r = DRHMRouter(N_LANES, seed=1)
    lanes = [r.lane_of([i]) for i in range(256)]
    assert lanes == [r.lane_of([i]) for i in range(256)]
    assert all(0 <= ln < N_LANES for ln in lanes)


def test_uniform_traffic_does_not_reseed():
    r = DRHMRouter(N_LANES, seed=0)
    rng = np.random.default_rng(0)
    depths = rng.poisson(6.0, N_LANES) + 1
    assert not r.maybe_reseed(depths)
    assert r.reseeds == 0


def test_skewed_depths_trigger_reseed_and_rebalance():
    """An adversarial stream (every seed routed to lane 0 under γ₀) must
    trigger a reseed, and the SAME seeds re-routed under the new γ must
    spread to ≤1.5× mean — the paper's dynamic-reseeding claim at traffic
    level."""
    r = DRHMRouter(N_LANES, n_bins=1024, seed=5)
    hot = [i for i in range(4096) if r.lane_of([i]) == 0]
    assert len(hot) > 300                     # ~1/8 of ids hit lane 0
    pre = np.bincount([r.lane_of([s]) for s in hot], minlength=N_LANES)
    assert utilization_spread(pre) == pytest.approx(N_LANES)
    depths = pre.astype(float)
    assert r.maybe_reseed(depths)             # max ≫ 1.5 × mean
    post = np.bincount([r.lane_of([s]) for s in hot], minlength=N_LANES)
    assert post.sum() == len(hot)
    assert utilization_spread(post) <= 1.5, post


def test_rebalance_preserves_exact_balance_for_every_subset():
    """Property (satellite 3): after rebalancing onto ANY non-empty active
    subset, the bin→lane map is an exact-balance bijection over exactly
    that subset — each active lane owns n_bins/n_active bins, inactive
    lanes own zero."""
    r = DRHMRouter(N_LANES, n_bins=1024, seed=11)
    rng = np.random.default_rng(0)
    for n_active in list(range(1, N_LANES + 1)) * 3:
        active = sorted(rng.choice(N_LANES, n_active, replace=False)
                        .tolist())
        r.rebalance(active)
        counts = np.bincount(r.lane_map(), minlength=N_LANES)
        assert (counts[active] == r.n_bins // n_active).all(), counts
        inactive = [i for i in range(N_LANES) if i not in active]
        assert (counts[inactive] == 0).all(), counts
        # routing agrees with the map: live traffic only hits survivors
        lanes = r.route_many(np.arange(512, dtype=np.uint64))
        assert set(np.unique(lanes)) <= set(active)


def test_rebalance_bumps_epoch_and_noops_on_same_set():
    r = DRHMRouter(4, n_bins=256, seed=2)
    e0 = r.epoch
    r.rebalance([0, 2, 3])
    assert r.epoch == e0 + 1 and r.rebalances == 1
    r.rebalance([3, 2, 0])                    # same set, any order: no-op
    assert r.epoch == e0 + 1 and r.rebalances == 1
    r.rebalance([0, 1, 2, 3])                 # growth rebalances again
    assert r.epoch == e0 + 2
    with pytest.raises(ValueError, match="at least one"):
        r.rebalance([])
    with pytest.raises(ValueError, match="out of range"):
        r.rebalance([0, 9])


def test_reseed_respects_the_active_set():
    """γ reseeds and failover rebalances compose: after both, the map is
    still balanced over the active subset only."""
    r = DRHMRouter(N_LANES, n_bins=1024, seed=4)
    r.rebalance([0, 3, 5, 6])
    before = r.lane_map()
    r.reseed()
    after = r.lane_map()
    assert (before != after).mean() > 0.5     # the map really moved
    counts = np.bincount(after, minlength=N_LANES)
    assert (counts[[0, 3, 5, 6]] == r.n_bins // 4).all()
    assert counts[[1, 2, 4, 7]].sum() == 0
    # skew judgment ignores inactive lanes: a huge queue on a dead lane
    # (its pinned backlog draining) must not churn the map
    depths = np.zeros(N_LANES)
    depths[1] = 1000.0
    depths[[0, 3, 5, 6]] = 5.0
    assert not r.maybe_reseed(depths)


def test_in_flight_requests_drain_on_the_old_map():
    """A request's lane is pinned at submit; reseeding only redirects
    future traffic."""
    cfg, params, indptr, indices, store = build_world("sage", 256, 1024, 8,
                                                      seed=0)
    srv = ClusterServer("sage", cfg, params, indptr, indices, store,
                        n_lanes=4, fanouts=(2, 2), backend="dense", seed=0)
    with srv:
        reqs = srv.submit_many([[i % 256] for i in range(16)])
        lanes_at_submit = [r.lane for r in reqs]
        srv.router.reseed()
        srv.drain()
        assert [r.lane for r in reqs] == lanes_at_submit
        served = np.asarray(srv.lane_stats()["served"])
        routed = np.bincount(lanes_at_submit, minlength=4)
        assert (served == routed).all()


# ---------------------------------------------------------------------------
# Cluster serving — replicated / stacked (device-count agnostic)
# ---------------------------------------------------------------------------

ARCHS = ("gcn", "sage", "gat")


@pytest.mark.parametrize("arch", ARCHS)
def test_replicated_parity_vs_offline_replay(arch):
    cfg, params, indptr, indices, store = build_world(arch, 512, 2048, 16,
                                                      seed=0)
    srv = ClusterServer(arch, cfg, params, indptr, indices, store,
                        n_lanes=4, fanouts=(3, 2), backend="dense", seed=0,
                        max_batch_seeds=4)
    with srv:
        srv.warmup()
        reqs = srv.submit_many(
            [np.random.default_rng(i).integers(0, 512, 1 + i % 4)
             for i in range(24)])
        srv.drain()
        for r in reqs:
            ref = srv.offline_replay(r)
            assert r.result.shape == ref.shape
            np.testing.assert_allclose(r.result, ref, atol=1e-5)


def test_zero_steady_state_recompiles():
    cfg, params, indptr, indices, store = build_world("gcn", 512, 2048, 16,
                                                      seed=0)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=4, fanouts=(3, 2), backend="dense", seed=0,
                        max_batch_seeds=4)
    with srv:
        srv.warmup()
        for r in srv.submit_many([[i % 512] for i in range(32)]):
            r.wait(120)
        builds = srv.steps.builds
        for r in srv.submit_many([[(7 * i) % 512] for i in range(32)]):
            r.wait(120)
        assert srv.steps.builds == builds


def test_cluster_rejects_bad_requests_and_archs():
    cfg, params, indptr, indices, store = build_world("gcn", 128, 512, 8,
                                                      seed=0)
    with pytest.raises(ValueError, match="single-device only"):
        ClusterServer("schnet", cfg, params, indptr, indices, store)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=2, fanouts=(2, 2), backend="dense")
    with srv:
        with pytest.raises(ValueError, match="out of range"):
            srv.submit([999])
        with pytest.raises(ValueError, match="seeds"):
            srv.submit_many([[]])


def test_e2e_reseed_rebalances_skewed_stream():
    """Adversarial burst through the live server: the router reseeds and
    post-reseed routing spreads to ≤1.5× mean."""
    cfg, params, indptr, indices, store = build_world("sage", 1024, 4096, 8,
                                                      seed=0)
    srv = ClusterServer("sage", cfg, params, indptr, indices, store,
                        n_lanes=4, fanouts=(2, 2), backend="dense", seed=0,
                        max_batch_seeds=4, reseed_check_every=16)
    probe = DRHMRouter(4, seed=0)
    hot = [i for i in range(1024) if probe.lane_of([i]) == 0]
    rng = np.random.default_rng(1)
    with srv:
        srv.warmup()
        srv.submit_many([[int(rng.choice(hot))] for _ in range(256)])
        srv.drain()
        info = srv.router.info()
        assert info["reseeds"] >= 1
        post = np.sum([np.asarray(c, float)
                       for c in info["routed_per_epoch"][1:]], axis=0)
        assert post.sum() > 64                # plenty routed after reseed
        assert utilization_spread(post) <= 1.5
        st = srv.stats()
        assert st["n_served"] == 256


# ---------------------------------------------------------------------------
# Multi-device: sharded residency + mesh placement (direct on the CI leg)
# ---------------------------------------------------------------------------

def _trace(n_nodes, n=48, k=2, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n_nodes, k) for _ in range(n)]


@multi_device
@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_bitwise_matches_replicated(arch):
    cfg, params, indptr, indices, store = build_world(arch, 512, 2048, 16,
                                                      seed=0)
    results = {}
    for mode in ("replicated", "sharded"):
        srv = ClusterServer(arch, cfg, params, indptr, indices, store,
                            n_lanes=N_LANES, mode=mode, fanouts=(3, 2),
                            backend="dense", seed=0, max_batch_seeds=4)
        with srv:
            srv.warmup()
            reqs = srv.submit_many(_trace(512))
            srv.drain()
            results[mode] = np.concatenate([r.result for r in reqs])
    assert np.array_equal(results["sharded"], results["replicated"])


@multi_device
def test_mesh_placement_bitwise_matches_stacked():
    cfg, params, indptr, indices, store = build_world("gcn", 512, 2048, 16,
                                                      seed=0)
    results = {}
    for placement in ("stacked", "mesh"):
        srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                            n_lanes=N_LANES, placement=placement,
                            fanouts=(3, 2), backend="dense", seed=0,
                            max_batch_seeds=4)
        with srv:
            srv.warmup()
            reqs = srv.submit_many(_trace(512))
            srv.drain()
            results[placement] = np.concatenate([r.result for r in reqs])
    assert np.array_equal(results["mesh"], results["stacked"])


@multi_device
def test_sharded_parity_vs_offline_replay():
    cfg, params, indptr, indices, store = build_world("gcn", 512, 2048, 16,
                                                      seed=0)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=N_LANES, mode="sharded", fanouts=(3, 2),
                        backend="dense", seed=0, max_batch_seeds=4)
    with srv:
        srv.warmup()
        reqs = srv.submit_many(_trace(512, n=24))
        srv.drain()
        for r in reqs:
            np.testing.assert_allclose(r.result, srv.offline_replay(r),
                                       atol=1e-5)


def test_sharded_requires_devices():
    if jax.device_count() >= N_LANES:
        pytest.skip("only meaningful on a single-device run")
    cfg, params, indptr, indices, store = build_world("gcn", 128, 512, 8,
                                                      seed=0)
    with pytest.raises(ValueError, match="devices"):
        ClusterServer("gcn", cfg, params, indptr, indices, store,
                      n_lanes=N_LANES, mode="sharded")


SUBPROCESS_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.launch.gnn_serve import build_world
from repro.serve import ClusterServer

cfg, params, indptr, indices, store = build_world("gcn", 256, 1024, 8, 0)
rng = np.random.default_rng(3)
traces = [rng.integers(0, 256, 2) for _ in range(32)]
out = {}
for mode in ("replicated", "sharded"):
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=8, mode=mode, fanouts=(2, 2),
                        backend="dense", seed=0, max_batch_seeds=4)
    with srv:
        srv.warmup()
        reqs = srv.submit_many(traces)
        srv.drain()
        out[mode] = np.concatenate([r.result for r in reqs])
        ref = np.concatenate([srv.offline_replay(r) for r in reqs[:8]])
        got = np.concatenate([r.result for r in reqs[:8]])
        assert abs(got - ref).max() <= 1e-5
assert np.array_equal(out["sharded"], out["replicated"])
print("CLUSTER_OK")
"""


def test_sharded_cluster_subprocess():
    """Tier-1 single-device runs still exercise the 8-device sharded path
    (the CI multi-device leg runs the direct tests above instead)."""
    if jax.device_count() >= N_LANES:
        pytest.skip("direct multi-device tests cover this")
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SMOKE], capture_output=True,
        text=True,
        # JAX_PLATFORMS must survive into the child: without it jax may
        # probe accelerator backends (e.g. a baked-in libtpu) and hang for
        # minutes on metadata timeouts
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "CLUSTER_OK" in proc.stdout
