"""GNN serving subsystem: bucket structure, forest sampler, step parity,
engine end-to-end, and the zero-recompile steady-state contract."""
import numpy as np
import pytest

import jax

from repro.data import synthetic as syn
from repro.serve import compute
from repro.serve.buckets import (all_buckets, bucket_for,
                                 build_bucket_structure, stack_trees)
from repro.serve.compute import FeatureStore
from repro.serve.engine import GNNServer, offline_inference, offline_replay
from repro.sparse import sampler
from repro.sparse.graph import coo_to_csr

N, E, D = 400, 2000, 16
FANOUTS = (3, 2)


def _csr(seed=0):
    s, r = syn.powerlaw_graph(N, E, seed=seed)
    return coo_to_csr(s, r, N)[:2]


def _store(seed=1):
    rng = np.random.default_rng(seed)
    return FeatureStore.build(
        N, x=rng.normal(size=(N, D)).astype(np.float32),
        species=rng.integers(1, 9, N).astype(np.int32),
        pos=rng.normal(scale=2.0, size=(N, 3)).astype(np.float32))


def _trees(k, seed=2):
    indptr, indices = _csr()
    rng = np.random.default_rng(seed)
    return [sampler.sample_subgraph(indptr, indices,
                                    rng.integers(0, N, 1), FANOUTS, rng)
            for _ in range(k)]


# ---------------------------------------------------------------------------
# buckets & structure
# ---------------------------------------------------------------------------

def test_bucket_for_powers_of_two():
    assert [bucket_for(k, 16) for k in (1, 2, 3, 5, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    assert all_buckets(16) == (1, 2, 4, 8, 16)
    assert all_buckets(12) == (1, 2, 4, 8, 12)
    with pytest.raises(ValueError):
        bucket_for(17, 16)
    with pytest.raises(ValueError):
        bucket_for(0, 16)


def test_structure_matches_sampler_arithmetic():
    """The bucket's static senders/receivers must equal what the sampler
    emits for any batch of that size — they are the same arithmetic."""
    indptr, indices = _csr()
    rng = np.random.default_rng(0)
    for k in (1, 4):
        sub = sampler.sample_subgraph(indptr, indices,
                                      rng.integers(0, N, k), FANOUTS, rng)
        st = build_bucket_structure(k, FANOUTS)
        assert np.array_equal(st.senders, np.concatenate(sub.hop_senders))
        assert np.array_equal(st.receivers,
                              np.concatenate(sub.hop_receivers))
        assert st.n_nodes == sub.node_ids.shape[0]


def test_structure_triplets_are_tree_consistent():
    """Every triplet pairs an in-edge (k→j) with an out-edge (j→i): the
    in-edge's receiver slot must be the out-edge's sender slot."""
    st = build_bucket_structure(3, (3, 2, 2))
    assert st.n_triplets == sum(sampler.budget(3, (3, 2, 2))[1:])
    s, r = st.senders, st.receivers
    assert np.array_equal(r[st.t_in], s[st.t_out])


def test_stack_trees_layout_and_padding():
    trees = _trees(3)
    node_ids, hop_valid = stack_trees(trees, 4, FANOUTS)
    st = build_bucket_structure(4, FANOUTS)
    assert node_ids.shape[0] == st.n_nodes
    assert hop_valid.shape[0] == st.n_hop_edges
    # seeds land in slots 0..k-1; the padding tree's lanes are dead
    for t, tree in enumerate(trees):
        assert node_ids[t] == tree.node_ids[0]
    assert node_ids[3] == -1
    # every valid edge connects the same global pair as in its source tree
    for t, tree in enumerate(trees):
        sub_ids, sub_valid = stack_trees([tree], 1, FANOUTS)
        st1 = build_bucket_structure(1, FANOUTS)
        v1 = sub_valid
        pairs1 = {(sub_ids[a], sub_ids[b])
                  for a, b in zip(st1.senders[v1], st1.receivers[v1])}
        # tree t's edges within the stacked batch
        vb = np.zeros(st.n_hop_edges, bool)
        off, toff = 0, 0
        sizes = sampler.budget(1, FANOUTS)
        for h, sz in enumerate(sizes):
            vb[off + t * sz: off + (t + 1) * sz] = tree.hop_valid[h]
            off += sz * 4
        pairsb = {(node_ids[a], node_ids[b])
                  for a, b in zip(st.senders[vb], st.receivers[vb])}
        assert pairs1 == pairsb


def test_stack_trees_overflow_raises():
    with pytest.raises(ValueError):
        stack_trees(_trees(3), 2, FANOUTS)


# ---------------------------------------------------------------------------
# forest sampler (serving data plane)
# ---------------------------------------------------------------------------

def test_forest_grouping_invariance():
    """A tree's draws depend only on (key, tree_key) — not on which other
    trees share the vectorized call."""
    indptr, indices = _csr()
    seeds = np.array([5, 77, 200, 5])        # duplicate seed ids too
    keys = np.array([3, 9, 11, 42], np.uint64)
    joint = sampler.sample_forest(indptr, indices, seeds, FANOUTS, key=7,
                                  tree_keys=keys)
    for i in range(4):
        solo = sampler.sample_forest(indptr, indices, seeds[i:i + 1],
                                     FANOUTS, key=7,
                                     tree_keys=keys[i:i + 1])[0]
        assert np.array_equal(joint[i].node_ids, solo.node_ids)
        for h in range(len(FANOUTS)):
            assert np.array_equal(joint[i].hop_valid[h], solo.hop_valid[h])
    # trees with the same seed but different keys differ (independent
    # streams), same key reproduces exactly
    again = sampler.sample_forest(indptr, indices, seeds[:1], FANOUTS, key=7,
                                  tree_keys=keys[:1])[0]
    assert np.array_equal(joint[0].node_ids, again.node_ids)


def test_forest_edges_exist_in_graph():
    indptr, indices = _csr()
    tree = sampler.sample_forest(indptr, indices, np.array([17]), FANOUTS,
                                 key=0, tree_keys=np.array([1], np.uint64))[0]
    for h in range(len(FANOUTS)):
        v = tree.hop_valid[h]
        src = tree.node_ids[tree.hop_senders[h][v]]
        dst = tree.node_ids[tree.hop_receivers[h][v]]
        for sg, dg in zip(src, dst):
            assert sg in indices[indptr[dg]:indptr[dg + 1]]


# ---------------------------------------------------------------------------
# step parity: batched-bucketed == one tree at a time
# ---------------------------------------------------------------------------

def _parity(arch, cfg, mod, backends, k=4, tol=1e-5):
    store = _store()
    trees = _trees(k)
    params = mod.init_params(jax.random.key(0), cfg)
    loops = arch == "gcn"
    stk = build_bucket_structure(k, FANOUTS, with_loops=loops)
    st1 = build_bucket_structure(1, FANOUTS, with_loops=loops)
    ref = None
    for backend in backends:
        stepk = compute.build_infer_step(arch, cfg, store, stk,
                                         backend=backend)
        step1 = compute.build_infer_step(arch, cfg, store, st1,
                                         backend=backend)
        batched = np.asarray(stepk(params, *stack_trees(trees, k, FANOUTS)))
        singles = np.concatenate(
            [np.asarray(step1(params, *stack_trees([t], 1, FANOUTS)))
             for t in trees])
        dev = float(np.abs(batched - singles).max())
        assert dev <= tol, (arch, backend, dev)
        assert np.isfinite(batched).all()
        if ref is None:
            ref = batched
        else:                                 # executors agree with dense
            assert float(np.abs(batched - ref).max()) <= 1e-4


def test_parity_gcn():
    from repro.models.gnn import gcn
    _parity("gcn", gcn.GCNConfig(d_in=D, d_hidden=8, n_classes=5), gcn,
            ("dense", "chunked", "pallas"))


def test_parity_sage():
    from repro.models.gnn import sage
    _parity("sage", sage.SAGEConfig(d_in=D, d_hidden=8, n_classes=5), sage,
            ("dense", "pallas"))


def test_parity_gin():
    from repro.models.gnn import gin
    _parity("gin", gin.GINConfig(d_in=D, d_hidden=8, n_classes=5), gin,
            ("dense", "chunked"))


def test_parity_gat():
    from repro.models.gnn import gat
    _parity("gat", gat.GATConfig(d_in=D, d_hidden=4, n_heads=2, n_classes=5),
            gat, ("dense",))


def test_parity_geometric():
    from repro.models.gnn import dimenet, schnet
    _parity("schnet",
            schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=8),
            schnet, ("dense",), k=2)
    _parity("dimenet",
            dimenet.DimeNetConfig(n_blocks=1, d_hidden=8, n_bilinear=2,
                                  n_spherical=3),
            dimenet, ("dense",), k=2)


def test_padding_lanes_do_not_leak():
    """A bucket-4 batch holding 2 real trees must produce the same outputs
    for those trees as a bucket-2 batch — padding lanes contribute zero."""
    from repro.models.gnn import gin
    cfg = gin.GINConfig(d_in=D, d_hidden=8, n_classes=5)
    params = gin.init_params(jax.random.key(0), cfg)
    store = _store()
    trees = _trees(2)
    out4 = np.asarray(compute.build_infer_step(
        "gin", cfg, store, build_bucket_structure(4, FANOUTS))(
            params, *stack_trees(trees, 4, FANOUTS)))
    out2 = np.asarray(compute.build_infer_step(
        "gin", cfg, store, build_bucket_structure(2, FANOUTS))(
            params, *stack_trees(trees, 2, FANOUTS)))
    np.testing.assert_allclose(out4[:2], out2, atol=1e-5)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def _server(backend="dense", **kw):
    from repro.models.gnn import gcn
    indptr, indices = _csr()
    cfg = gcn.GCNConfig(d_in=D, d_hidden=8, n_classes=5)
    params = gcn.init_params(jax.random.key(0), cfg)
    return GNNServer("gcn", cfg, params, indptr, indices, _store(),
                     fanouts=FANOUTS, backend=backend, max_batch_seeds=8,
                     max_wait_ms=2.0, n_workers=2, seed=0, **kw)


def test_engine_serves_all_exactly_once_with_parity():
    rng = np.random.default_rng(3)
    with _server() as server:
        server.warmup()
        reqs = [server.submit(rng.integers(0, N, size=rng.integers(1, 4)))
                for _ in range(17)]
        server.drain(timeout=120)
        st = server.stats()
        assert st["n_served"] == 17
        for r in reqs:
            assert r.done and r.result.shape == (r.n_seeds, 5)
            # offline replay: deterministic re-sample + bucket-1 inference
            ref = offline_replay(server, r)
            assert float(np.abs(r.result - ref).max()) <= 1e-5
        # batches were actually formed, and bucket capacity covers the
        # trees each batch carried (bucket sizes include padding lanes)
        assert st["n_batches"] >= 1
        assert sum(int(b) * c for b, c in st["bucket_counts"].items()) >= \
            sum(r.n_seeds for r in reqs)


def test_engine_zero_recompiles_after_warmup():
    with _server() as server:
        server.warmup()                       # whole ladder: 1,2,4,8
        warm = server.steps.builds
        assert warm == len(all_buckets(8))
        rng = np.random.default_rng(4)
        for _ in range(3):                    # repeated steady-state traffic
            reqs = [server.submit([int(s)]) for s in rng.integers(0, N, 20)]
            server.drain(timeout=120)
            for r in reqs:
                assert r.done
        assert server.steps.builds == warm, \
            "steady-state serving must not rebuild bucket steps"
        assert server.stats()["recompiles"] == warm


def test_engine_second_request_in_bucket_zero_recompiles():
    """Bucket-cache contract without explicit warmup: the first request
    compiles its bucket, the second identical one must not."""
    with _server() as server:
        server.submit([7]).wait(120)
        builds = server.steps.builds
        server.submit([9]).wait(120)
        assert server.steps.builds == builds


def test_engine_offline_inference_matches_result_trees():
    with _server() as server:
        req = server.submit([3, 5])
        req.wait(120)
        ref = offline_inference(server, req.trees)
        np.testing.assert_allclose(req.result, ref, atol=1e-5)


def test_engine_rejects_bad_requests_and_survives():
    """Malformed requests fail the CALLER, not a worker thread; the server
    keeps serving afterwards (regression: a worker exception used to kill
    its lane and hang all subsequent traffic routed to it)."""
    with _server() as server:
        with pytest.raises(ValueError):
            server.submit([N + 5])                # out of range
        with pytest.raises(ValueError):
            server.submit([-1])
        with pytest.raises(ValueError):
            server.submit(np.arange(9))           # exceeds bucket cap (8)
        with pytest.raises(ValueError):
            server.submit([])
        out = server.submit([3]).wait(120)        # the lane still works
        assert out.shape == (1, 5)


def test_engine_close_serves_everything_submitted():
    """close() is graceful: requests still in the sampling pipeline at
    close time are served, not dropped (regression: the engine thread used
    to flush before the samplers finished, hanging their wait())."""
    server = _server()
    rng = np.random.default_rng(8)
    reqs = [server.submit([int(s)]) for s in rng.integers(0, N, 50)]
    server.close()                                # no drain first
    for r in reqs:
        out = r.wait(timeout=5.0)                 # must not hang
        assert out.shape == (1, 5)


def test_engine_duplicate_and_isolated_seeds():
    """Duplicate seed ids in one batch and zero-degree seeds must serve."""
    from repro.models.gnn import gin
    # a graph whose last node is isolated (regression: CSR end-of-array)
    s = np.array([0, 1, 2, 0], np.int64)
    r = np.array([1, 2, 0, 2], np.int64)
    indptr, indices, _ = coo_to_csr(s, r, 5)   # nodes 3, 4 isolated
    cfg = gin.GINConfig(d_in=D, d_hidden=8, n_classes=3)
    params = gin.init_params(jax.random.key(1), cfg)
    store = FeatureStore.build(
        5, x=np.random.default_rng(0).normal(size=(5, D)).astype(np.float32))
    with GNNServer("gin", cfg, params, indptr, indices, store,
                   fanouts=FANOUTS, max_batch_seeds=8, max_wait_ms=1.0,
                   n_workers=1, seed=0) as server:
        req = server.submit([4, 4, 2, 4])      # duplicates + isolated
        out = req.wait(120)
        assert out.shape == (4, 3)
        assert np.isfinite(out).all()
        # duplicate seeds get identical answers (same tree stream per lane?
        # no — per-lane streams differ, but isolated nodes have no valid
        # edges at all, so every lane reduces to the self feature)
        np.testing.assert_allclose(out[0], out[1], atol=1e-5)
        np.testing.assert_allclose(out[0], out[3], atol=1e-5)
