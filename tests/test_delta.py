"""Streaming graph deltas: incremental re-pack parity vs cold packing.

The live-mutation contract (DESIGN.md §16): after ANY interleaving of edge
inserts/deletes and epoch flushes, the incrementally maintained CSR and
dedup-chunk layouts are plan-equal to a cold ``plan_from_graph`` over the
compacted edge arrays — structure bitwise, aggregates within 1e-5 — and the
dedup-chunk stats (chunk count, width, hub splits) agree exactly.

Property tests run under real ``hypothesis`` when installed, else the
deterministic shim."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                       # pragma: no cover
    from _hypothesis_shim import given, settings, st

import jax.numpy as jnp

from repro.sparse import backend as sb
from repro.sparse.delta import (DeltaGraphError, DeltaGraphState,
                                chunks_match, plans_match)
from repro.sparse.graph import coo_to_csr

N = 24          # node count: small enough that collisions/hubs are common


def _seed_graph(seed, e=64):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, N, e)
    r = rng.integers(0, N, e)
    w = rng.normal(size=e).astype(np.float32)
    return s, r, w, rng


def _assert_cold_parity(d: DeltaGraphState, seed=0):
    # CSR bitwise vs a cold sort of the compacted canonical arrays
    indptr, indices = d.csr()
    ci, cc, _ = coo_to_csr(d._s, d._r, d.n_nodes)
    np.testing.assert_array_equal(indptr, ci)
    np.testing.assert_array_equal(indices, cc)
    # chunk layouts bitwise vs a cold pack
    for inc, cold in zip(d.repack(), d.cold_repack()):
        ok, detail = chunks_match(inc, cold)
        assert ok, detail
    # full plan parity + aggregate parity through a real executor
    pa, pb = d.plan(), d.cold_plan()
    ok, detail = plans_match(pa, pb)
    assert ok, detail
    rng = np.random.default_rng(seed + 999)
    x = jnp.asarray(rng.normal(size=(pa.n_rows, 8)).astype(np.float32))
    for be in ("chunked", "pallas"):
        ya = np.asarray(sb.aggregate(pa, None, x, backend=be))
        yb = np.asarray(sb.aggregate(pb, None, x, backend=be))
        np.testing.assert_allclose(ya, yb, atol=1e-5)
    # stats the plan records must agree with make_plan's view
    stats = d.chunk_stats()
    fwd_cold = d.cold_repack()[0]
    assert stats["n_chunks"] == fwd_cold.u_cols.shape[0]
    assert stats["chunk_width"] == fwd_cold.u_cols.shape[1]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.lists(st.sampled_from(["ins", "del", "flush"]),
                min_size=4, max_size=40))
def test_random_interleaving_matches_cold_pack(seed, script):
    s, r, w, rng = _seed_graph(seed)
    d = DeltaGraphState(s, r, N, weights=w)
    for op in script:
        if op == "ins":
            d.insert_edge(int(rng.integers(0, N)), int(rng.integers(0, N)),
                          float(rng.normal()))
        elif op == "del" and d.n_edges + d.pending > 1:
            # delete a live edge (range-validated booking raises on absent)
            k = int(rng.integers(0, d._s.size))
            try:
                d.delete_edge(int(d._s[k]), int(d._r[k]))
            except DeltaGraphError:
                pass          # every copy already booked for deletion
        else:
            d.flush()
    d.flush()
    _assert_cold_parity(d, seed)


def test_empty_delta_flush_is_identity():
    s, r, w, _ = _seed_graph(3)
    d = DeltaGraphState(s, r, N, weights=w)
    before = d.csr()
    res = d.flush()                       # nothing buffered
    assert (res.inserted, res.deleted, res.dirty_blocks) == (0, 0, 0)
    assert res.epoch == 1
    after = d.csr()
    np.testing.assert_array_equal(before[0], after[0])
    np.testing.assert_array_equal(before[1], after[1])
    _assert_cold_parity(d)


def test_delete_all_edges_of_a_row():
    s, r, w, _ = _seed_graph(5, e=48)
    d = DeltaGraphState(s, r, N, weights=w)
    row = int(r[0])                        # receiver row = CSR row
    for k in np.nonzero(r == row)[0]:
        d.delete_edge(int(s[k]), int(r[k]))
    d.flush()
    indptr, _ = d.csr()
    assert indptr[row + 1] - indptr[row] == 0
    _assert_cold_parity(d)


def test_delete_every_edge_then_rebuild():
    s, r, w, rng = _seed_graph(7, e=20)
    d = DeltaGraphState(s, r, N, weights=w)
    for k in range(s.size):
        d.delete_edge(int(s[k]), int(r[k]))
    d.flush()
    assert d.n_edges == 0
    _assert_cold_parity(d)
    for _ in range(16):
        d.insert_edge(int(rng.integers(0, N)), int(rng.integers(0, N)))
    d.flush()
    assert d.n_edges == 16
    _assert_cold_parity(d)


def test_delete_absent_edge_raises_and_leaves_state_clean():
    d = DeltaGraphState(np.array([0, 1]), np.array([1, 2]), 4)
    with pytest.raises(DeltaGraphError):
        d.delete_edge(3, 3)
    d.delete_edge(0, 1)
    with pytest.raises(DeltaGraphError):
        d.delete_edge(0, 1)                # only copy already booked
    assert d.pending == 1
    d.flush()
    assert d.n_edges == 1
    _assert_cold_parity(d)


def test_insert_cancelled_by_delete_before_flush():
    d = DeltaGraphState(np.array([0]), np.array([1]), 4)
    d.insert_edge(2, 3)
    d.delete_edge(2, 3)                    # cancels the pending insert
    assert d.pending == 0
    d.flush()
    assert d.n_edges == 1
    _assert_cold_parity(d)


def test_out_of_range_mutations_rejected():
    d = DeltaGraphState(np.array([0]), np.array([1]), 4)
    with pytest.raises(DeltaGraphError):
        d.insert_edge(4, 0)
    with pytest.raises(DeltaGraphError):
        d.insert_edge(0, -1)


def test_distributed_backend_has_no_delta_path():
    s, r, w, _ = _seed_graph(11)
    d = DeltaGraphState(s, r, N, weights=w)
    with pytest.raises(DeltaGraphError):
        d.plan(backends=("dense", "distributed"))


def test_incremental_beats_cold_on_sparse_deltas():
    """Sanity (not the perf gate — cluster_bench owns that): a small delta
    on a big graph re-chunks only the dirty blocks."""
    rng = np.random.default_rng(0)
    n, e = 4096, 60_000
    d = DeltaGraphState(rng.integers(0, n, e), rng.integers(0, n, e), n)
    for _ in range(32):
        d.insert_edge(int(rng.integers(0, n)), int(rng.integers(0, n)))
    res = d.flush()
    assert res.dirty_blocks < res.clean_blocks
