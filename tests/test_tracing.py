"""NeuraScope tracing (DESIGN.md §14): span-tree completeness properties.

The contract under test, end to end: every **accepted** request — served,
retried, re-routed across a lane kill, deadline-expired, or force-failed at
close — yields **exactly one** complete span tree with **exactly one**
terminal span (``settle`` XOR ``error``), and tracing disabled allocates
nothing at all.  ``tracing.verify_trace``/``verify_traces`` is the single
verifier shared with ``neurascope --check``, so a CI smoke failure and a
test failure here always agree on what "well-formed" means.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.launch.gnn_serve import build_world
from repro.serve import (ChaosInjector, ClusterServer, GNNServer, LaneFault,
                         Overloaded, TelemetryHub, percentiles_ms)
from repro.serve.tracing import (SCHEMA_VERSION, TERMINAL_SPANS, Tracer,
                                 verify_trace, verify_traces)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from tests._hypothesis_shim import given, settings, st

N = 4                                     # lanes in every cluster test


def _world(arch="sage", n_nodes=256, seed=0):
    return build_world(arch, n_nodes, 4 * n_nodes, 8, seed=seed)


def _server(world, **kw):
    cfg, params, indptr, indices, store = world
    kw.setdefault("fanouts", (2, 2))
    kw.setdefault("backend", "dense")
    kw.setdefault("max_batch_seeds", 4)
    return GNNServer("sage", cfg, params, indptr, indices, store, **kw)


def _cluster(world, chaos=None, **kw):
    cfg, params, indptr, indices, store = world
    kw.setdefault("n_lanes", N)
    kw.setdefault("fanouts", (2, 2))
    kw.setdefault("backend", "dense")
    kw.setdefault("seed", 0)
    kw.setdefault("max_batch_seeds", 4)
    kw.setdefault("telemetry_interval", 0.02)
    kw.setdefault("tracing", True)
    return ClusterServer("sage", cfg, params, indptr, indices, store,
                         chaos=chaos, **kw)


def _assert_one_tree_per_request(tracer, reqs, allow_shed=0):
    """The core property: exactly one well-formed trace per accepted
    request, terminal agreeing with the request's settled state."""
    recs = tracer.traces()
    assert verify_traces(recs) == []
    by_id = {r["trace"]: r for r in recs if r["trace"] is not None}
    rids = {r.rid for r in reqs}
    assert set(by_id) >= rids, \
        f"missing traces for rids {sorted(rids - set(by_id))[:5]}"
    for req in reqs:
        spans = by_id[req.rid]["spans"]
        terminal = spans[-1]["name"]
        assert req.n_settles == 1
        if req.error is None:
            assert terminal == "settle", \
                f"rid {req.rid} served but terminal is {terminal}"
        else:
            assert terminal == "error", \
                f"rid {req.rid} failed but terminal is {terminal}"
    assert tracer.stats()["open"] == 0          # nothing half-finished


# ---------------------------------------------------------------------------
# Tracer unit behaviour (pure host logic, virtual time)
# ---------------------------------------------------------------------------

def test_tracer_records_and_ring_bound():
    t = [0.0]
    tr = Tracer(capacity=4, clock=lambda: t[0], t0=0.0)
    for i in range(10):
        tr.span(i, "sample", 0.0, 1.0, {"lane": 0})
        tr.settle(i, "settle", 1.0, 1.0)
    recs = tr.traces()
    assert len(recs) == 4                        # ring keeps the newest
    assert [r["trace"] for r in recs] == [6, 7, 8, 9]
    assert verify_traces(recs) == []
    st_ = tr.stats()
    assert st_["traces"] == 10 and st_["spans"] == 20 and st_["open"] == 0
    # record shape: versioned, t0-relative, attrs inlined
    rec = recs[0]
    assert rec["kind"] == "trace"
    assert rec["schema_version"] == SCHEMA_VERSION
    assert rec["spans"][0] == {"name": "sample", "t0": 0.0, "t1": 1.0,
                               "lane": 0}


def test_tracer_drops_late_spans_after_settlement():
    tr = Tracer(capacity=8, clock=lambda: 0.0, t0=0.0)
    tr.span(1, "sample", 0.0, 1.0)
    tr.settle(1, "settle", 1.0, 1.0)
    tr.span(1, "retry", 2.0, 2.0)                # raced straggler
    tr.settle(1, "error", 2.0, 2.0)              # raced duplicate terminal
    recs = tr.traces()
    assert len(recs) == 1 and len(recs[0]["spans"]) == 2
    assert tr.stats()["dropped"] == 2
    assert tr.stats()["open"] == 0               # nothing reopened


def test_tracer_point_and_sink():
    flushed = []
    tr = Tracer(capacity=8, clock=lambda: 1.5, t0=1.0, sink=flushed.append)
    tr.point("shed", {"n": 3})
    assert len(flushed) == 1
    rec = flushed[0]
    assert rec["trace"] is None
    assert rec["spans"] == [{"name": "shed", "t0": 0.5, "t1": 0.5, "n": 3}]
    assert verify_trace(rec) == []


def test_verify_trace_catches_malformations():
    ok = {"kind": "trace", "schema_version": SCHEMA_VERSION, "trace": 1,
          "spans": [{"name": "sample", "t0": 0.0, "t1": 1.0},
                    {"name": "settle", "t0": 1.0, "t1": 1.0}]}
    assert verify_trace(ok) == []
    no_terminal = dict(ok, spans=[{"name": "sample", "t0": 0.0, "t1": 1.0}])
    assert any("terminal" in p for p in verify_trace(no_terminal))
    two_terminals = dict(ok, spans=ok["spans"] + [
        {"name": "error", "t0": 1.0, "t1": 1.0}])
    assert any("terminal" in p for p in verify_trace(two_terminals))
    not_last = dict(ok, spans=list(reversed(ok["spans"])))
    assert any("not last" in p for p in verify_trace(not_last))
    backwards = dict(ok, spans=[{"name": "sample", "t0": 2.0, "t1": 1.0},
                                ok["spans"][1]])
    assert any("malformed interval" in p for p in verify_trace(backwards))
    stale = dict(ok, schema_version=SCHEMA_VERSION + 1)
    assert any("schema_version" in p for p in verify_trace(stale))
    empty = dict(ok, spans=[])
    assert any("no spans" in p for p in verify_trace(empty))
    dup = verify_traces([ok, dict(ok)])
    assert any("duplicate" in p for p in dup)
    # shed point-traces carry trace=None and must NOT count as duplicates
    shed = {"kind": "trace", "schema_version": SCHEMA_VERSION, "trace": None,
            "spans": [{"name": "shed", "t0": 0.0, "t1": 0.0}]}
    assert verify_traces([shed, dict(shed)]) == []


@settings(max_examples=20)
@given(st.lists(st.integers(min_value=0, max_value=49), min_size=1,
                max_size=60))
def test_tracer_property_random_interleavings(ids):
    """Arbitrary span/settle interleavings over reused ids: every flushed
    record is well-formed and ids never produce two live records (the
    closed-set guard)."""
    flushed = []
    tr = Tracer(capacity=128, clock=lambda: 0.0, t0=0.0,
                sink=flushed.append)
    settled = set()
    for i, trace in enumerate(ids):
        if trace in settled:
            tr.span(trace, "retry", float(i), float(i))      # late — dropped
            continue
        tr.span(trace, "sample", float(i), float(i) + 0.5)
        if i % 3 != 0:
            tr.settle(trace, "settle" if i % 2 else "error",
                      float(i) + 0.5, float(i) + 0.5)
            settled.add(trace)
    # settle the stragglers the way drain would
    for trace in list(tr.open_traces()):
        tr.settle(trace, "error", 99.0, 99.0, {"error": "DrainTimeout"})
    assert verify_traces(flushed) == []
    assert {r["trace"] for r in flushed} == set(ids)
    assert tr.stats()["open"] == 0


# ---------------------------------------------------------------------------
# Disabled tracing: zero allocation, zero stats surface
# ---------------------------------------------------------------------------

def test_tracing_disabled_allocates_nothing():
    srv = _server(_world())
    with srv:
        assert srv.tracer is None
        reqs = [srv.submit([i % 256]) for i in range(8)]
        srv.drain(timeout=120)
        assert all(r.error is None for r in reqs)
        assert "tracing" not in srv.stats()
    csrv = _cluster(_world(), tracing=False)
    with csrv:
        assert csrv.tracer is None
        for r in csrv.submit_many([[i % 256] for i in range(8)]):
            r.wait(120)
        assert "tracing" not in csrv.stats()


# ---------------------------------------------------------------------------
# Engine span trees: happy path, retries, deadlines, close
# ---------------------------------------------------------------------------

def test_engine_happy_path_span_trees():
    srv = _server(_world(), tracing=True)
    with srv:
        reqs = [srv.submit([i % 256]) for i in range(16)]
        srv.drain(timeout=120)
        _assert_one_tree_per_request(srv.tracer, reqs)
        rec = srv.tracer.traces()[0]
        names = [s["name"] for s in rec["spans"]]
        assert names == ["sample", "queue_wait", "bucket_pack", "dispatch",
                         "settle"]
        # stats surface for operators
        ts = srv.stats()["tracing"]
        assert ts["traces"] == 16 and ts["dropped"] == 0


def test_engine_deadline_expiry_yields_error_terminal():
    srv = _server(_world(), tracing=True, max_wait_ms=40.0)
    with srv:
        # a deadline in the past expires in the reaper before any dispatch
        req = srv.submit([3], deadline_ms=0.01)
        req.wait_done(120)
        srv.drain(timeout=120)
        assert req.error is not None
        _assert_one_tree_per_request(srv.tracer, [req])
        rec = next(r for r in srv.tracer.traces() if r["trace"] == req.rid)
        assert rec["spans"][-1]["name"] == "error"
        assert rec["spans"][-1]["error"] == "DeadlineExceeded"


@settings(max_examples=5)
@given(st.integers(min_value=1, max_value=24))
def test_engine_property_every_accepted_request_traced(n_requests):
    srv = _server(_world(), tracing=True)
    with srv:
        reqs = [srv.submit([(7 * i) % 256]) for i in range(n_requests)]
        srv.drain(timeout=120)
        _assert_one_tree_per_request(srv.tracer, reqs)


# ---------------------------------------------------------------------------
# Cluster span trees under chaos: kill, retry, shed, forced close
# ---------------------------------------------------------------------------

def test_cluster_happy_path_has_route_span():
    srv = _cluster(_world())
    with srv:
        reqs = srv.submit_many([[i % 256] for i in range(16)])
        srv.drain(timeout=120)
        _assert_one_tree_per_request(srv.tracer, reqs)
        rec = srv.tracer.traces()[0]
        names = [s["name"] for s in rec["spans"]]
        assert names[0] == "route" and names[-1] == "settle"
        assert "sample" in names and "dispatch" in names


def test_cluster_lane_kill_traces_reroutes():
    chaos = ChaosInjector(seed=0, lane_faults=[LaneFault(lane=1, at_round=2)])
    srv = _cluster(_world(), chaos=chaos, stall_timeout=0.15,
                   restart_after=0.4)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(64)])
        srv.drain(timeout=120)
        _assert_one_tree_per_request(srv.tracer, reqs)
        assert srv.stats()["reroutes"] >= 1
        # the stranded queue's traces carry the reroute hop
        rerouted = [r for r in srv.tracer.traces()
                    if any(s["name"] == "reroute" for s in r["spans"])]
        assert rerouted, "lane kill produced no reroute spans"
        for rec in rerouted:
            hop = next(s for s in rec["spans"] if s["name"] == "reroute")
            assert hop["from"] != hop["to"]


def test_cluster_transient_step_fault_traces_retry():
    chaos = ChaosInjector(seed=0, step_fault_rounds=(1,))
    srv = _cluster(_world(), chaos=chaos, max_retries=1)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(16)])
        srv.drain(timeout=120)
        _assert_one_tree_per_request(srv.tracer, reqs)
        retried = [r for r in srv.tracer.traces()
                   if any(s["name"] == "retry" for s in r["spans"])]
        assert retried, "injected step fault produced no retry spans"
        for rec in retried:                      # retried AND settled once
            assert rec["spans"][-1]["name"] in TERMINAL_SPANS


def _all_lanes_wedged():
    return ChaosInjector(seed=0, lane_faults=[LaneFault(lane=i)
                                              for i in range(N)])


def test_cluster_shed_emits_point_traces_and_close_settles_backlog():
    srv = _cluster(_world(), chaos=_all_lanes_wedged(), stall_timeout=60.0,
                   shed_queue_hwm=8, shed_sustain_ticks=1)
    accepted = srv.submit_many([[i % 256] for i in range(24)])
    deadline = time.monotonic() + 30
    while not srv._shedding and time.monotonic() < deadline:
        time.sleep(0.01)
    shed = 0
    for i in range(16):
        try:
            accepted.append(srv.submit([i % 256]))
        except Overloaded:
            shed += 1
    srv.close()                        # flush serves the wedged backlog
    assert shed >= 1
    recs = srv.tracer.traces()
    assert verify_traces(recs) == []
    shed_recs = [r for r in recs if r["trace"] is None]
    assert len(shed_recs) == shed
    assert all(r["spans"][0]["name"] == "shed" for r in shed_recs)
    _assert_one_tree_per_request(srv.tracer, accepted)


# ---------------------------------------------------------------------------
# Flight recorder hardening: schema versioning + size-bounded rotation
# ---------------------------------------------------------------------------

def test_jsonl_schema_version_and_rotation(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    t = [0.0]
    hub = TelemetryHub(2, jsonl_path=path, jsonl_max_bytes=2048,
                       clock=lambda: t[0])
    tracer = Tracer(capacity=64, clock=lambda: t[0], t0=hub.t0,
                    sink=hub.emit)
    for i in range(40):
        t[0] += 0.01
        hub.event("tick", i=i)
        tracer.span(i, "sample", t[0], t[0])
        tracer.settle(i, "settle", t[0], t[0])
    hub.stop()
    assert hub.jsonl_rotations >= 1
    assert os.path.exists(path) and os.path.exists(path + ".1")
    recs = []
    for p in (path + ".1", path):
        with open(p) as f:
            recs += [json.loads(line) for line in f]
    assert all(r["schema_version"] == SCHEMA_VERSION for r in recs)
    kinds = {r["kind"] for r in recs}
    assert kinds == {"event", "trace"}
    assert verify_traces([r for r in recs if r["kind"] == "trace"]) == []
    # rotation is single-slot: total retained bytes stay bounded
    total = os.path.getsize(path) + os.path.getsize(path + ".1")
    assert total <= 2 * 2048 + 512


def test_percentiles_ms_shared_helper():
    assert percentiles_ms([]) == {"p50_ms": 0.0, "p95_ms": 0.0,
                                  "p99_ms": 0.0}
    out = percentiles_ms([0.001 * (i + 1) for i in range(100)])
    assert out["p50_ms"] == pytest.approx(50.5, rel=0.02)
    assert out["p95_ms"] == pytest.approx(95.05, rel=0.02)
    assert out["p99_ms"] == pytest.approx(99.01, rel=0.02)
    assert out["p50_ms"] <= out["p95_ms"] <= out["p99_ms"]
