"""Shared pytest plumbing.

Per-test hard timeout, dependency-free: set ``PYTEST_PER_TEST_TIMEOUT``
(seconds) and every test body runs under a ``signal.alarm`` that raises
``TimeoutError`` when it fires.  The CI chaos leg sets this so a wedged
lane/supervisor interaction fails the leg with a stack trace instead of
hanging the job until the runner's global kill.  Unset (the default, and
all local runs) the hook is a no-op.  POSIX-only (``signal.alarm``) and
main-thread-only — exactly the CI environment; anywhere else it disables
itself rather than misfire.
"""
import os
import signal
import threading

import pytest

_TIMEOUT = float(os.environ.get("PYTEST_PER_TEST_TIMEOUT", "0") or 0)


def _usable() -> bool:
    return (_TIMEOUT > 0 and hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread())


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if not _usable():
        yield
        return

    def _fire(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the per-test timeout "
            f"({_TIMEOUT:g}s via PYTEST_PER_TEST_TIMEOUT)")

    old = signal.signal(signal.SIGALRM, _fire)
    signal.setitimer(signal.ITIMER_REAL, _TIMEOUT)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
