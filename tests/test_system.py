"""End-to-end system behaviour: training convergence, checkpoint/restart,
failure injection + recovery, straggler accounting — the fault-tolerance
contract of the training runtime."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import synthetic as syn
from repro.optim import adamw
from repro.train import loop as train_loop


def _gcn_job(tmp_path, n_steps, **kw):
    from repro.launch.train import _gnn_setup
    cfg = registry.get_config("gcn-cora", reduced=False)
    params, step, batches = _gnn_setup("gcn-cora", cfg, 0, full=True)
    state = train_loop.TrainState(params=params,
                                 opt_state=adamw.init_state(params))
    loop_cfg = train_loop.TrainLoopConfig(
        n_steps=n_steps, ckpt_every=5, ckpt_dir=str(tmp_path), log_every=1000)
    return state, jax.jit(step), batches, loop_cfg


def test_training_converges(tmp_path):
    state, step, batches, cfg = _gcn_job(tmp_path / "a", 30)
    state, hist = train_loop.run(state, step, batches, cfg, log=lambda *_: None)
    assert hist["loss"][-1] < 0.5 * hist["loss"][0]


def test_checkpoint_restart_resumes(tmp_path):
    ckpt = tmp_path / "b"
    state, step, batches, cfg = _gcn_job(ckpt, 10)
    state, _ = train_loop.run(state, step, batches, cfg, log=lambda *_: None)
    assert state.step == 10
    # new process-equivalent: fresh state, same ckpt dir, more steps
    state2, step2, batches2, cfg2 = _gcn_job(ckpt, 20)
    state2, hist2 = train_loop.run(state2, step2, batches2, cfg2,
                                   log=lambda *_: None)
    assert state2.step == 20
    assert len(hist2["loss"]) == 10      # only steps 11..20 re-ran


def test_failure_injection_recovers(tmp_path):
    state, step, batches, cfg = _gcn_job(tmp_path / "c", 15)
    boom = {"armed": True}

    def injector(s):
        if s == 8 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    state, hist = train_loop.run(state, step, batches, cfg,
                                 fail_injector=injector, log=lambda *_: None)
    assert state.step == 15
    assert hist["retries"] == 1


def test_too_many_failures_aborts(tmp_path):
    state, step, batches, cfg = _gcn_job(tmp_path / "d", 10)

    def always_fail(s):
        raise RuntimeError("dead node")

    with pytest.raises(RuntimeError, match="aborting"):
        train_loop.run(state, step, batches, cfg, fail_injector=always_fail,
                       log=lambda *_: None)


def test_lm_loss_decreases():
    from repro.models.lm import transformer as T
    cfg = registry.get_config("qwen3-0.6b", reduced=True)
    params = T.init_params(jax.random.key(0), cfg)
    opt = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=1e-3)
    toks = jnp.asarray(syn.token_batch(4, 64, cfg.vocab))

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(T.loss_fn)(p, cfg, toks)
        p, o, _ = adamw.apply_updates(p, g, o, ocfg)
        return p, o, loss

    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3


def test_compressed_grads_error_feedback():
    """int8 + error feedback: long-run average ≈ true gradient."""
    from repro.optim import compression
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    residual = compression.init_residual(g_true)
    acc = jnp.zeros_like(g_true["w"])
    for _ in range(50):
        dec, residual = compression.error_feedback_compress(g_true, residual)
        acc = acc + dec["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true["w"]),
                               atol=2e-2)
