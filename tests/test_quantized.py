"""int8 quantized fast path (``sparse/quantize.py``, ``pallas_q8``):
scale contract, scale-derived parity bounds, resident features, and the
compression zero-block guard (DESIGN.md §12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from benchmarks.backend_sweep import aggregate_q8_bound_for
from repro.data.synthetic import powerlaw_graph
from repro.kernels.gustavson_spmm.gustavson_spmm import _auto_d_tile
from repro.optim import compression
from repro.sparse import backend as sparse_backend
from repro.sparse import quantize
from repro.sparse.plan import make_plan
from repro.sparse.spgemm import make_spgemm_plan


def _plan_x(n, e, d, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    s, r = powerlaw_graph(n, e + 64, seed=seed)
    s, r = s[:e], r[:e]
    vals = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(dtype))
    plan = make_plan(s, r, n, edge_weight=vals,
                     backends=sparse_backend.ALL_BACKENDS, chunk=min(512, e))
    return plan, x


# ---------------------------------------------------------------------------
# quantization contract
# ---------------------------------------------------------------------------

def test_chunk_tiles_roundtrip_error_within_half_scale():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(6 * 8, 16)).astype(np.float32) * 3.0
    q8, scale = quantize.quantize_chunk_tiles(a, 6)
    assert q8.dtype == jnp.int8 and scale.shape == (6,)
    deq = np.asarray(q8, np.float32).reshape(6, -1) * np.asarray(scale)[:, None]
    err = np.abs(deq - a.reshape(6, -1))
    # symmetric rounding: per-entry error ≤ scale/2
    assert np.all(err <= np.asarray(scale)[:, None] * 0.5 + 1e-7)


def test_chunk_tiles_zero_tile_exact_and_scale_one():
    a = np.zeros((2 * 4, 8), np.float32)
    a[4:] = 1.0                       # second chunk non-zero
    q8, scale = quantize.quantize_chunk_tiles(a, 2)
    assert float(scale[0]) == 1.0     # all-zero chunk: guard scale
    assert np.all(np.asarray(q8)[:4] == 0)


def test_chunk_tiles_empty_layout():
    q8, scale = quantize.quantize_chunk_tiles(np.zeros((0, 8), np.float32), 0)
    assert q8.shape == (0, 8) and scale.shape == (0,)


@given(st.integers(1, 5), st.sampled_from([1, 3, 8, 16, 33]),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_feature_tiles_roundtrip(seed, d, d_tile):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(12, d)).astype(np.float32) * (seed + 1)
    q8, scale = quantize.quantize_feature_tiles(x, d_tile)
    assert scale.shape == (-(-d // d_tile),)
    per_col = np.repeat(np.asarray(scale), d_tile)[:d]
    deq = np.asarray(q8, np.float32) * per_col[None, :]
    assert np.all(np.abs(deq - x) <= per_col[None, :] * 0.5 + 1e-7)


def test_quantized_features_is_jit_transparent():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                    jnp.float32)
    qf = quantize.quantize_features(x, 8)
    out = jax.jit(lambda q: q.q8.astype(jnp.float32).sum() + q.scale.sum())(qf)
    assert np.isfinite(float(out))


def test_q8_gate_nan_fails():
    assert quantize.q8_gate(0.0, 0.0)
    assert quantize.q8_gate(1.0, 1.0)
    assert not quantize.q8_gate(float("nan"), 1.0)
    assert not quantize.q8_gate(2.0, 1.0)


# ---------------------------------------------------------------------------
# aggregate parity within the scale-derived bound
# ---------------------------------------------------------------------------

@given(st.sampled_from([(64, 256), (128, 512), (200, 800)]),
       st.sampled_from([8, 32, 48]), st.integers(0, 99),
       st.sampled_from(["f32", "bf16"]))
@settings(max_examples=8, deadline=None)
def test_aggregate_q8_within_bound(ne, d, seed, dtype):
    n, e = ne
    dt = np.float32 if dtype == "f32" else np.float32  # x cast below
    plan, x = _plan_x(n, e, d, seed=seed, dtype=dt)
    if dtype == "bf16":
        x = x.astype(jnp.bfloat16).astype(jnp.float32)
    ref = sparse_backend.aggregate(plan, None, x, backend="dense")
    out = sparse_backend.aggregate(plan, None, x, backend="pallas_q8")
    dev = float(jnp.abs(ref - out).max())
    bound = aggregate_q8_bound_for(plan, x)
    assert quantize.q8_gate(dev, bound), (dev, bound)


def test_aggregate_q8_hub_graph_within_bound():
    # star graph: one receiver with every edge — forces hub row splitting
    n, e = 64, 256
    s = np.random.default_rng(3).integers(0, n, e)
    r = np.zeros(e, np.int64)
    vals = np.random.default_rng(4).normal(size=e).astype(np.float32)
    plan = make_plan(s, r, n, edge_weight=vals,
                     backends=sparse_backend.ALL_BACKENDS, chunk=128)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(n, 16)),
                    jnp.float32)
    ref = sparse_backend.aggregate(plan, None, x, backend="dense")
    out = sparse_backend.aggregate(plan, None, x, backend="pallas_q8")
    dev = float(jnp.abs(ref - out).max())
    assert quantize.q8_gate(dev, aggregate_q8_bound_for(plan, x))


def test_aggregate_q8_resident_features_bit_identical():
    plan, x = _plan_x(128, 512, 32, seed=7)
    dt = plan.ell_d_tile or _auto_d_tile(x.shape[1])
    qf = quantize.quantize_features(x, dt)
    in_trace = sparse_backend.aggregate(plan, None, x, backend="pallas_q8")
    resident = sparse_backend.aggregate(plan, None, qf, backend="pallas_q8")
    assert np.array_equal(np.asarray(in_trace), np.asarray(resident))


def test_aggregate_q8_resident_scale_shape_validated():
    plan, x = _plan_x(64, 256, 32, seed=1)
    bad = quantize.QuantizedFeatures(
        q8=jnp.zeros((64, 32), jnp.int8), scale=jnp.ones((99,), jnp.float32))
    with pytest.raises(ValueError):
        sparse_backend.aggregate(plan, None, bad, backend="pallas_q8")


def test_aggregate_q8_fwdbwd_runs_and_is_finite():
    plan, x = _plan_x(64, 256, 16, seed=2)
    v0 = jnp.ones_like(plan.base_vals)

    def loss(v, xx, nm):
        return jnp.mean(sparse_backend.aggregate(plan, v, xx, backend=nm)**2)

    gd = jax.grad(loss, argnums=(0, 1))(v0, x, "dense")
    gq = jax.grad(loss, argnums=(0, 1))(v0, x, "pallas_q8")
    for ref, got in zip(gd, gq):
        assert got.shape == ref.shape
        assert bool(jnp.isfinite(got).all())
        # straight-through backward: close to the f32 gradient, not exact
        assert float(jnp.abs(ref - got).max()) < 0.2


# ---------------------------------------------------------------------------
# SpGEMM parity within the scale-derived bound
# ---------------------------------------------------------------------------

def _spgemm_dev_bound(plan, av=None, bv=None):
    ref = sparse_backend.spgemm(plan, backend="dense")
    if av is not None:
        out = sparse_backend.spgemm(plan, jnp.asarray(av), jnp.asarray(bv),
                                    backend="pallas_q8")
    else:
        out = sparse_backend.spgemm(plan, backend="pallas_q8")
    dev = float(jnp.abs(ref - out).max()) if plan.nnz_out else 0.0
    bound = quantize.spgemm_q8_bound(plan.width, plan.ell_out_block,
                                     plan.n_blocks, plan.ell_a_scale,
                                     plan.slab_scale)
    return dev, bound


@given(st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_spgemm_q8_square_within_bound(seed):
    n, e = 96, 384
    s, r = powerlaw_graph(n, e + 64, seed=seed)
    s, r = s[:e], r[:e]
    av = np.random.default_rng(seed).normal(size=e).astype(np.float32)
    plan = make_spgemm_plan(r, s, n, r, s, n, a_vals=av, b_vals=av,
                            chunk=512)
    dev, bound = _spgemm_dev_bound(plan)
    assert quantize.q8_gate(dev, bound), (dev, bound)


def test_spgemm_q8_rectangular_within_bound():
    # A (40 × 64) · B (64 × 24) — all three dims distinct
    rng = np.random.default_rng(11)
    ar, ac = rng.integers(0, 40, 300), rng.integers(0, 64, 300)
    br, bc = rng.integers(0, 64, 250), rng.integers(0, 24, 250)
    av = rng.normal(size=300).astype(np.float32)
    bv = rng.normal(size=250).astype(np.float32)
    plan = make_spgemm_plan(ar, ac, 40, br, bc, 64, 24,
                            a_vals=av, b_vals=bv, chunk=256)
    dev, bound = _spgemm_dev_bound(plan)
    assert quantize.q8_gate(dev, bound), (dev, bound)
    # output values land on the exact C = A·B CSR structure
    out = sparse_backend.spgemm(plan, backend="pallas_q8")
    assert out.shape == (plan.nnz_out,)


def test_spgemm_q8_traced_vals_match_baked():
    n, e = 80, 320
    s, r = powerlaw_graph(n, e + 64, seed=13)
    s, r = s[:e], r[:e]
    av = np.random.default_rng(13).normal(size=e).astype(np.float32)
    plan = make_spgemm_plan(r, s, n, r, s, n, a_vals=av, b_vals=av,
                            chunk=512)
    baked = sparse_backend.spgemm(plan, backend="pallas_q8")
    traced = sparse_backend.spgemm(plan, jnp.asarray(av), jnp.asarray(av),
                                   backend="pallas_q8")
    # same values in, same quantization in: identical outputs
    assert np.allclose(np.asarray(baked), np.asarray(traced), atol=1e-5)


# ---------------------------------------------------------------------------
# optim.compression zero-block guard (regression)
# ---------------------------------------------------------------------------

def test_compression_zero_block_scale_guard():
    x = jnp.zeros((512,), jnp.float32).at[300].set(5.0)
    q, scale = compression.quantize_int8(x, block=256)
    s = np.asarray(scale).reshape(-1)
    assert s[0] == 1.0                      # all-zero block: guard scale
    assert np.isfinite(1.0 / s).all()       # no inf/NaN in scale arithmetic
    back = compression.dequantize_int8(q, scale, x.shape, x.dtype)
    assert np.all(np.asarray(back[:256]) == 0.0)
    assert abs(float(back[300]) - 5.0) <= float(s[1]) * 0.5 + 1e-6
