"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import mha_causal
from repro.kernels.gustavson_spmm.gustavson_spmm import spmm_blocked_ell
from repro.kernels.gustavson_spmm.ref import spmm_blocked_ell_ref
from repro.kernels.sddmm.ops import edge_scores
from repro.kernels.sddmm.ref import sddmm_ref
from repro.sparse.graph import pack_blocked_ell


@pytest.mark.parametrize("n,e,d,block_rows", [
    (32, 120, 8, 8), (64, 400, 128, 8), (100, 777, 33, 16), (16, 16, 256, 8),
])
def test_gustavson_spmm_shapes(n, e, d, block_rows):
    rng = np.random.default_rng(e)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    ell = pack_blocked_ell(rows, cols, vals, n, n, block_rows=block_rows,
                           nnz_multiple=32)
    args = (jnp.asarray(ell.cols), jnp.asarray(ell.row_local),
            jnp.asarray(ell.vals), jnp.asarray(ell.remaining), jnp.asarray(x))
    out = spmm_blocked_ell(*args, block_rows=block_rows)
    ref = spmm_blocked_ell_ref(*args, block_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_gustavson_empty_rows():
    """Rolling-eviction counters: blocks with zero nnz evict zeros."""
    n, d = 32, 16
    rows = np.array([0, 0, 1])
    cols = np.array([3, 4, 5])
    vals = np.ones(3, np.float32)
    x = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    ell = pack_blocked_ell(rows, cols, vals, n, n, block_rows=8,
                           nnz_multiple=32)
    out = spmm_blocked_ell(jnp.asarray(ell.cols), jnp.asarray(ell.row_local),
                           jnp.asarray(ell.vals), jnp.asarray(ell.remaining),
                           jnp.asarray(x), block_rows=8)
    assert float(jnp.abs(out[8:]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(out[0]), x[3] + x[4], rtol=1e-6)


@pytest.mark.parametrize("gather", ["dma", "stream"])
@pytest.mark.parametrize("n,e,d,d_tile", [
    (48, 333, 33, 16),    # D % d_tile != 0 → padded feature tiles
    (64, 500, 72, 24),    # 3 exact tiles
    (24, 100, 130, None), # auto single tile
])
def test_gustavson_dedup_chunks_feature_tiling(gather, n, e, d, d_tile):
    from repro.kernels.gustavson_spmm.gustavson_spmm import spmm_dedup_chunks
    from repro.kernels.gustavson_spmm.ref import spmm_dedup_chunks_ref
    from repro.sparse.graph import pack_dedup_chunks
    rng = np.random.default_rng(e + d)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ch = pack_dedup_chunks(rows, cols, vals, n, n, width_cap=32)
    args = (jnp.asarray(ch.u_cols), jnp.asarray(ch.remaining),
            jnp.asarray(ch.out_block), jnp.asarray(ch.first),
            jnp.asarray(ch.a))
    out = spmm_dedup_chunks(*args, x, block_rows=ch.block_rows,
                            n_blocks=ch.n_blocks, d_tile=d_tile,
                            gather=gather)
    ref = spmm_dedup_chunks_ref(args[0], args[2], args[4], x,
                                ch.block_rows, ch.n_blocks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,e,d", [(40, 256, 32), (17, 100, 64), (8, 64, 128)])
def test_sddmm_shapes(n, e, d):
    rng = np.random.default_rng(d)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    out = edge_scores(src, dst, x, y, edge_block=64)
    ref = sddmm_ref(src, dst, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,f,m,v,d", [(8, 4, 1, 50, 16), (16, 26, 1, 200, 64),
                                       (8, 3, 4, 77, 32)])
def test_embedding_bag_shapes(b, f, m, v, d):
    rng = np.random.default_rng(b + f)
    ids = jnp.asarray(rng.integers(0, v, (b, f, m)), jnp.int32)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    out = embedding_bag(ids, table, batch_tile=4)
    ref = embedding_bag_ref(ids, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (2, 128, 4, 2, 32, 32, 32), (1, 256, 2, 2, 64, 64, 128),
    (3, 64, 8, 1, 16, 16, 16),
])
def test_flash_attention_shapes(b, s, h, kv, hd, bq, bk):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    out = mha_causal(q, k, v, block_q=bq, block_k=bk)
    ref = mha_causal(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.bfloat16)
    out = mha_causal(q, k, v, block_q=32, block_k=32)
    ref = mha_causal(jnp.float32(q), jnp.float32(k), jnp.float32(v),
                     use_kernel=False)
    np.testing.assert_allclose(np.float32(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
