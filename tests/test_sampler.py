"""Neighbor sampler: static shapes, valid endpoints, determinism."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.sparse import sampler
from repro.sparse.graph import coo_to_csr


def _graph(n=200, e=2000, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    indptr, indices, _ = coo_to_csr(s, r, n)
    return indptr, indices, n


@given(st.integers(1, 16), st.lists(st.integers(1, 6), min_size=1,
                                    max_size=3), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_static_shapes(b, fanouts, seed):
    indptr, indices, n = _graph()
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, n, b)
    sub = sampler.sample_subgraph(indptr, indices, seeds, fanouts, rng)
    assert sub.node_ids.shape[0] == sampler.node_budget(b, fanouts)
    for h, f_budget in zip(range(len(fanouts)),
                           sampler.budget(b, fanouts)):
        assert sub.hop_senders[h].shape[0] == f_budget
        assert sub.hop_receivers[h].shape[0] == f_budget
        assert sub.hop_valid[h].shape[0] == f_budget
        # senders/receivers index INTO the node table
        assert sub.hop_senders[h].max() < sub.node_ids.shape[0]
        assert sub.hop_receivers[h].max() < sub.node_ids.shape[0]


def test_sampled_edges_exist_in_graph():
    indptr, indices, n = _graph()
    rng = np.random.default_rng(7)
    seeds = rng.integers(0, n, 8)
    sub = sampler.sample_subgraph(indptr, indices, seeds, (5, 3), rng)
    for h in range(2):
        v = sub.hop_valid[h]
        src_global = sub.node_ids[sub.hop_senders[h][v]]
        dst_global = sub.node_ids[sub.hop_receivers[h][v]]
        for sg, dg in zip(src_global[:50], dst_global[:50]):
            nbrs = indices[indptr[dg]:indptr[dg + 1]]
            assert sg in nbrs


def test_deterministic():
    indptr, indices, n = _graph()
    seeds = np.arange(4)
    a = sampler.sample_subgraph(indptr, indices, seeds, (4, 2),
                                np.random.default_rng(3))
    b = sampler.sample_subgraph(indptr, indices, seeds, (4, 2),
                                np.random.default_rng(3))
    assert np.array_equal(a.node_ids, b.node_ids)


# ---------------------------------------------------------------------------
# edge cases surfaced by serving traffic (regressions)
# ---------------------------------------------------------------------------

def test_isolated_trailing_seed_does_not_crash():
    """A zero-degree node whose CSR slice starts at the END of `indices`
    used to read out of bounds; it must yield an all-invalid tree."""
    indptr = np.array([0, 2, 2, 2], np.int64)     # nodes 1, 2 isolated
    indices = np.array([1, 2], np.int32)
    sub = sampler.sample_subgraph(indptr, indices, np.array([2]), (4, 2),
                                  np.random.default_rng(0))
    assert sub.node_ids[0] == 2
    assert not sub.hop_valid[0].any() and not sub.hop_valid[1].any()
    assert (sub.node_ids[1:] == -1).all()
    assert sub.node_ids.shape[0] == sampler.node_budget(1, (4, 2))


def test_edgeless_graph():
    sub = sampler.sample_subgraph(np.zeros(5, np.int64),
                                  np.zeros(0, np.int32),
                                  np.array([1, 3]), (3,),
                                  np.random.default_rng(0))
    assert not sub.hop_valid[0].any()
    assert sub.node_ids.shape[0] == sampler.node_budget(2, (3,))


def test_fanout_larger_than_degree_repeats_neighbors():
    # node 0 has exactly one neighbor (node 1); fanout 6 must fill the
    # fixed budget with repeats, all valid
    indptr = np.array([0, 1, 1], np.int64)
    indices = np.array([1], np.int32)
    sub = sampler.sample_subgraph(indptr, indices, np.array([0]), (6,),
                                  np.random.default_rng(0))
    assert sub.hop_valid[0].all()
    assert (sub.node_ids[1:] == 1).all()


def test_invalid_lane_children_stay_invalid():
    """Hops below a dead lane (isolated node) must not masquerade as real
    edges, even when the dummy substitute node has neighbors."""
    # node 0 has neighbors, node 2 is isolated (but not last — that path
    # never crashed, it silently sampled node 0's neighborhood)
    indptr = np.array([0, 2, 3, 3], np.int64)
    indices = np.array([1, 2, 0], np.int32)
    sub = sampler.sample_subgraph(indptr, indices, np.array([2]), (2, 2),
                                  np.random.default_rng(0))
    assert not sub.hop_valid[0].any()
    assert not sub.hop_valid[1].any(), \
        "children of an invalid lane leaked through as valid"


def test_duplicate_seeds_sample_independent_trees():
    indptr, indices, n = _graph()
    seeds = np.array([7, 7, 7])
    sub = sampler.sample_subgraph(indptr, indices, seeds, (5, 2),
                                  np.random.default_rng(1))
    assert sub.node_ids.shape[0] == sampler.node_budget(3, (5, 2))
    assert (sub.node_ids[:3] == 7).all()
    for h in range(2):
        assert sub.hop_valid[h].all()


def test_forest_matches_single_tree_semantics():
    """sample_forest pads/validates exactly like sample_subgraph at B=1
    (structure arrays identical; draws differ — counter vs rng stream)."""
    indptr, indices, n = _graph()
    trees = sampler.sample_forest(indptr, indices, np.array([3, 9]), (4, 2),
                                  key=5)
    single = sampler.sample_subgraph(indptr, indices, np.array([3]), (4, 2),
                                     np.random.default_rng(0))
    for t in trees:
        assert t.node_ids.shape == single.node_ids.shape
        for h in range(2):
            assert np.array_equal(t.hop_senders[h], single.hop_senders[h])
            assert np.array_equal(t.hop_receivers[h],
                                  single.hop_receivers[h])
            assert t.hop_valid[h].shape == single.hop_valid[h].shape


def test_forest_isolated_and_edgeless():
    indptr = np.array([0, 2, 2, 2], np.int64)
    indices = np.array([1, 2], np.int32)
    t_iso = sampler.sample_forest(indptr, indices, np.array([2]), (3, 2),
                                  key=0)[0]
    assert not t_iso.hop_valid[0].any() and not t_iso.hop_valid[1].any()
    t_empty = sampler.sample_forest(np.zeros(4, np.int64),
                                    np.zeros(0, np.int32),
                                    np.array([1]), (3,), key=0)[0]
    assert not t_empty.hop_valid[0].any()
