"""Neighbor sampler: static shapes, valid endpoints, determinism."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.sparse import sampler
from repro.sparse.graph import coo_to_csr


def _graph(n=200, e=2000, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    indptr, indices, _ = coo_to_csr(s, r, n)
    return indptr, indices, n


@given(st.integers(1, 16), st.lists(st.integers(1, 6), min_size=1,
                                    max_size=3), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_static_shapes(b, fanouts, seed):
    indptr, indices, n = _graph()
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, n, b)
    sub = sampler.sample_subgraph(indptr, indices, seeds, fanouts, rng)
    assert sub.node_ids.shape[0] == sampler.node_budget(b, fanouts)
    for h, f_budget in zip(range(len(fanouts)),
                           sampler.budget(b, fanouts)):
        assert sub.hop_senders[h].shape[0] == f_budget
        assert sub.hop_receivers[h].shape[0] == f_budget
        assert sub.hop_valid[h].shape[0] == f_budget
        # senders/receivers index INTO the node table
        assert sub.hop_senders[h].max() < sub.node_ids.shape[0]
        assert sub.hop_receivers[h].max() < sub.node_ids.shape[0]


def test_sampled_edges_exist_in_graph():
    indptr, indices, n = _graph()
    rng = np.random.default_rng(7)
    seeds = rng.integers(0, n, 8)
    sub = sampler.sample_subgraph(indptr, indices, seeds, (5, 3), rng)
    for h in range(2):
        v = sub.hop_valid[h]
        src_global = sub.node_ids[sub.hop_senders[h][v]]
        dst_global = sub.node_ids[sub.hop_receivers[h][v]]
        for sg, dg in zip(src_global[:50], dst_global[:50]):
            nbrs = indices[indptr[dg]:indptr[dg + 1]]
            assert sg in nbrs


def test_deterministic():
    indptr, indices, n = _graph()
    seeds = np.arange(4)
    a = sampler.sample_subgraph(indptr, indices, seeds, (4, 2),
                                np.random.default_rng(3))
    b = sampler.sample_subgraph(indptr, indices, seeds, (4, 2),
                                np.random.default_rng(3))
    assert np.array_equal(a.node_ids, b.node_ids)
