"""NeuraSim model properties vs the paper's published results."""
import dataclasses

import numpy as np
import pytest

from repro.neurasim import datasets, machine, model


@pytest.fixture(scope="module")
def workloads():
    out = []
    for name in ("wiki-Vote", "facebook", "p2p-Gnutella31", "poisson3Da"):
        s, r, n = datasets.synth(name)
        out.append(model.stats_from_coo(s, r, n))
    return out


def test_calibration_band(workloads):
    """Simulated GOP/s within ±40% of paper per config (fit used more graphs)."""
    for cname, cfg in machine.CONFIGS.items():
        avg = np.mean([model.simulate_spgemm(w, cfg).gops for w in workloads])
        paper = machine.PAPER_NEURACHIP_GOPS[cname]
        assert 0.6 * paper < avg < 1.6 * paper, (cname, avg, paper)


def test_tile_ordering_matches_paper(workloads):
    """Paper Table 5: T4 < T16 < T64 at 128 GB/s; dual-HBM T64 much faster."""
    g = {c: np.mean([model.simulate_spgemm(w, cfg).gops for w in workloads])
         for c, cfg in machine.CONFIGS.items()}
    assert g["tile4"] < g["tile16"] <= g["tile64"] * 1.05
    t64b = dataclasses.replace(machine.TILE64, dram_bw_gbps=256.0)
    g64b = np.mean([model.simulate_spgemm(w, t64b).gops for w in workloads])
    assert g64b > 1.5 * g["tile64"]


def test_drhm_mapping_flattest_on_patterned():
    tags = (np.arange(300_000) * 32) % (1 << 16)   # ring-adversarial stride
    imb = {m: model.imbalance_factor(
        model.mapping_loads(tags, 32, m)) for m in
        ("ring", "modular", "random", "drhm")}
    assert imb["drhm"] < 0.25 * imb["ring"]
    assert imb["drhm"] < 1.5 * imb["random"]


def test_rolling_beats_barrier(workloads):
    w = workloads[0]
    roll = model.simulate_spgemm(w, machine.TILE16, eviction="rolling")
    barr = model.simulate_spgemm(w, machine.TILE16, eviction="barrier")
    assert roll.cycles < barr.cycles


def test_hacc_rolling_cpi_lower():
    r = model.sample_hacc_cpi("rolling", machine.TILE16, occupancy=0.6)
    b = model.sample_hacc_cpi("barrier", machine.TILE16, occupancy=0.6)
    assert r.mean() < 0.6 * b.mean()


def test_mmh4_is_sweet_spot():
    """Paper Fig 14: per-partial-product cost minimized at MMH4."""
    cpis = {k: model.sample_mmh_cpi(k, machine.TILE16).mean() / (k * 4)
            for k in (1, 2, 4, 8)}
    assert cpis[4] == min(cpis.values())


def test_speedup_headlines():
    """Paper headline: 22.1× MKL, 1.5× Gamma (we tolerate a ±45% band since
    the matrices are synthetic rebuilds)."""
    s, r, n = datasets.synth("poisson3Da")
    ws = [model.stats_from_coo(s, r, n)]
    for name in ("facebook", "wiki-Vote", "scircuit"):
        sg, rg, ng = datasets.synth(name)
        ws.append(model.stats_from_coo(sg, rg, ng))
    t16 = np.mean([model.simulate_spgemm(w, machine.TILE16).gops for w in ws])
    mkl = t16 / machine.PUBLISHED_GOPS["Xeon E5 (MKL)"]
    gamma = t16 / machine.PUBLISHED_GOPS["Gamma"]
    assert 0.55 * 22.1 < mkl < 1.45 * 22.1
    assert 0.55 * 1.5 < gamma < 1.45 * 1.5
