"""While-aware HLO cost model: trip-count multiplication on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_costs


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied():
    """20-iteration scan of a 128×128 matmul: ≈ 20 · 2·128³ flops."""
    n, iters = 128, 20
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def fn(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=iters)
        return out

    txt = _compiled_text(fn, x, w)
    flops, byts, coll = hlo_costs.corrected_costs(txt)
    expect = 2.0 * n * n * n * iters
    assert 0.9 * expect < flops < 1.3 * expect, (flops, expect)


def test_flat_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    txt = _compiled_text(lambda a, b: a @ b, a, b)
    flops, _, _ = hlo_costs.corrected_costs(txt)
    expect = 2 * 64 * 256 * 32
    assert 0.99 * expect < flops < 1.01 * expect


def test_bytes_scale_with_scan_length():
    n = 256
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def make(iters):
        def fn(x):
            def body(c, _):
                return jnp.tanh(c) * 1.0001, None
            out, _ = jax.lax.scan(body, x, None, length=iters)
            return out
        return fn

    _, b10, _ = hlo_costs.corrected_costs(_compiled_text(make(10), x))
    _, b40, _ = hlo_costs.corrected_costs(_compiled_text(make(40), x))
    assert 2.5 < b40 / b10 < 4.5


def test_shape_bytes():
    assert hlo_costs._shape_bytes("f32[4,8]{1,0}") == 128
    assert hlo_costs._shape_bytes("bf16[10]") == 20
    assert hlo_costs._shape_bytes("(f32[2,2], s32[3])") == 28
    assert hlo_costs._shape_bytes("pred[7]") == 7
