"""Decoupled SpGEMM (paper C1) and rolling eviction (C3) correctness."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.core import eviction, spgemm


def _dense_ref(rows, cols, vals, x, n):
    d = np.zeros((n, n), np.float32)
    np.add.at(d, (rows, cols), vals)
    return d @ x


@given(st.integers(4, 60), st.integers(1, 300), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_decoupled_spmm_matches_dense(n, e, d, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = spgemm.spmm(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                    jnp.asarray(x), n)
    np.testing.assert_allclose(np.asarray(y), _dense_ref(rows, cols, vals, x, n),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 128]))
@settings(max_examples=15, deadline=None)
def test_rolling_eviction_equals_full(seed, chunk):
    """C3 invariant: chunked accumulation == one-shot accumulation."""
    rng = np.random.default_rng(seed)
    n, e, d = 40, 512, 8
    rows = jnp.asarray(rng.integers(0, n, e))
    cols = jnp.asarray(rng.integers(0, n, e))
    vals = jnp.asarray(rng.normal(size=e).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    full = spgemm.spmm(rows, cols, vals, x, n)
    chunked = spgemm.spmm_chunked(rows, cols, vals, x, n, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_masked_padding_contributes_nothing():
    rng = np.random.default_rng(0)
    n, e, d = 20, 100, 4
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    valid = np.ones(e, bool)
    valid[50:] = False
    y = spgemm.spmm_masked(jnp.asarray(rows), jnp.asarray(cols),
                           jnp.asarray(vals), jnp.asarray(x), n,
                           jnp.asarray(valid))
    ref = _dense_ref(rows[:50], cols[:50], vals[:50], x, n)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)


def test_bloat_percent_eq1():
    """Paper Eq. (1) on a hand-checkable case."""
    assert eviction.bloat_percent(100, 50) == 100.0
    assert eviction.bloat_percent(50, 50) == 0.0


def test_interim_pp_and_output_nnz_tiny():
    # A = [[1,1],[0,1]] (COO), A@A: pp = row-wise expansion count
    rows = np.array([0, 0, 1])
    cols = np.array([0, 1, 1])
    pp = eviction.interim_pp_count(cols, np.bincount(rows, minlength=2))
    # row0 of A references B rows 0 (2 nnz) and 1 (1 nnz); row1 → B row 1
    assert pp == 2 + 1 + 1
    nnz = eviction.output_nnz(rows, cols, rows, cols, 2, 2)
    assert nnz == 3  # [[1,2],[0,1]]
