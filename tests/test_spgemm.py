"""Decoupled SpGEMM (paper C1), rolling eviction (C3), and the
sparse-output SpGEMM engine (symbolic + numeric phases, DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.core import eviction, spgemm
from repro.sparse import backend as sb
from repro.sparse.spgemm import (hash_bucket, hash_dedup_row_nnz,
                                 make_spgemm_plan, symbolic, two_hop_graph)


def _dense_ref(rows, cols, vals, x, n):
    d = np.zeros((n, n), np.float32)
    np.add.at(d, (rows, cols), vals)
    return d @ x


@given(st.integers(4, 60), st.integers(1, 300), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_decoupled_spmm_matches_dense(n, e, d, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = spgemm.spmm(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                    jnp.asarray(x), n)
    np.testing.assert_allclose(np.asarray(y), _dense_ref(rows, cols, vals, x, n),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 128]))
@settings(max_examples=15, deadline=None)
def test_rolling_eviction_equals_full(seed, chunk):
    """C3 invariant: chunked accumulation == one-shot accumulation."""
    rng = np.random.default_rng(seed)
    n, e, d = 40, 512, 8
    rows = jnp.asarray(rng.integers(0, n, e))
    cols = jnp.asarray(rng.integers(0, n, e))
    vals = jnp.asarray(rng.normal(size=e).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    full = spgemm.spmm(rows, cols, vals, x, n)
    chunked = spgemm.spmm_chunked(rows, cols, vals, x, n, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_masked_padding_contributes_nothing():
    rng = np.random.default_rng(0)
    n, e, d = 20, 100, 4
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    valid = np.ones(e, bool)
    valid[50:] = False
    y = spgemm.spmm_masked(jnp.asarray(rows), jnp.asarray(cols),
                           jnp.asarray(vals), jnp.asarray(x), n,
                           jnp.asarray(valid))
    ref = _dense_ref(rows[:50], cols[:50], vals[:50], x, n)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)


def test_bloat_percent_eq1():
    """Paper Eq. (1) on a hand-checkable case."""
    assert eviction.bloat_percent(100, 50) == 100.0
    assert eviction.bloat_percent(50, 50) == 0.0


def test_interim_pp_and_output_nnz_tiny():
    # A = [[1,1],[0,1]] (COO), A@A: pp = row-wise expansion count
    rows = np.array([0, 0, 1])
    cols = np.array([0, 1, 1])
    pp = eviction.interim_pp_count(cols, np.bincount(rows, minlength=2))
    # row0 of A references B rows 0 (2 nnz) and 1 (1 nnz); row1 → B row 1
    assert pp == 2 + 1 + 1
    nnz = eviction.output_nnz(rows, cols, rows, cols, 2, 2)
    assert nnz == 3  # [[1,2],[0,1]]
    # the historical core.spgemm entry delegates to the same count
    assert spgemm.interim_partial_products(
        cols, np.bincount(rows, minlength=2)) == pp


def test_spgemm_via_dense_size_guard():
    """The densifying oracle refuses anything beyond tiny sizes."""
    a = jnp.zeros((1,), jnp.int32)
    v = jnp.ones((1,), jnp.float32)
    with pytest.raises(ValueError, match="sparse-output engine"):
        spgemm.spgemm_via_dense(a, a, v, 1, a, a, v, 1 << 13, 1 << 13)


# ---------------------------------------------------------------------------
# Sparse-output SpGEMM engine: symbolic phase
# ---------------------------------------------------------------------------

def _coo(rng, n_rows, n_cols, e):
    return (rng.integers(0, n_rows, e), rng.integers(0, n_cols, e),
            rng.normal(size=e).astype(np.float32))


def _dense_of(rows, cols, vals, n_rows, n_cols):
    d = np.zeros((n_rows, n_cols), np.float32)
    np.add.at(d, (rows, cols), vals)
    return d


@given(st.integers(2, 40), st.integers(2, 40), st.integers(2, 40),
       st.integers(0, 200), st.integers(0, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_symbolic_structure_matches_dense_oracle(n, m, k, ea, eb, seed):
    """Property: symbolic row-nnz / structure == the boolean dense product,
    on fully random rectangular operands (including empty ones)."""
    rng = np.random.default_rng(seed)
    ar, ac, _ = _coo(rng, n, m, ea)
    br, bc, _ = _coo(rng, m, k, eb)
    sym = symbolic(ar, ac, n, br, bc, m, k)
    a = _dense_of(ar, ac, np.ones(ea, np.float32), n, m) > 0
    b = _dense_of(br, bc, np.ones(eb, np.float32), m, k) > 0
    c = a.astype(np.int64) @ b.astype(np.int64) > 0
    assert sym.nnz_out == int(c.sum())
    np.testing.assert_array_equal(sym.row_nnz, c.sum(1))
    assert c[sym.c_row, sym.c_col].all()
    # Eq.-1 interim count agrees with both existing implementations
    deg_b = np.bincount(br, minlength=m)
    assert sym.pp_interim == eviction.interim_pp_count(ac, deg_b)


def test_symbolic_matches_dense_on_powerlaw():
    from repro.data.synthetic import powerlaw_graph
    s, r = powerlaw_graph(300, 1800, seed=11)
    sym = symbolic(r, s, 300, r, s, 300)
    a = _dense_of(r, s, np.ones(r.size, np.float32), 300, 300) > 0
    c = a.astype(np.int64) @ a.astype(np.int64) > 0
    assert sym.nnz_out == int(c.sum())
    np.testing.assert_array_equal(sym.row_nnz, c.sum(1))


def test_symbolic_pp_matches_neurasim_walk():
    """Engine-measured stats == the independent NeuraSim Eq.-1 walk."""
    from repro.data.synthetic import powerlaw_graph
    from repro.neurasim.model import stats_from_coo
    s, r = powerlaw_graph(256, 1024, seed=4)
    sym = symbolic(s, r, 256, s, r, 256)
    w = stats_from_coo(s.astype(np.int64), r.astype(np.int64), 256)
    assert sym.pp_interim == w.pp_interim
    assert sym.nnz_out == w.nnz_out


@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 256]))
@settings(max_examples=10, deadline=None)
def test_hash_dedup_variant_matches_merge(seed, pad_width):
    """The HashPad-style linear-probe dedup discovers the same per-row
    output nnz as the merge (np.unique) symbolic phase."""
    rng = np.random.default_rng(seed)
    ar, ac, _ = _coo(rng, 24, 24, 120)
    br, bc, _ = _coo(rng, 24, 24, 120)
    sym = symbolic(ar, ac, 24, br, bc, 24)
    pp_row = sym.c_row[sym.pp_slot]
    pp_col = sym.c_col[sym.pp_slot]
    row_nnz, stats = hash_dedup_row_nnz(pp_row, pp_col, 24, pad_width,
                                        seed=seed)
    np.testing.assert_array_equal(row_nnz, sym.row_nnz)
    assert stats["occupancy_peak"] <= pad_width


def test_hash_dedup_high_bloat_row():
    """A row whose pp count exceeds the pad but whose *distinct* tags fit
    (the paper's high-bloat regime) must dedup fine; only a row with more
    distinct tags than pad lines overflows."""
    pp_row = np.zeros(70, np.int64)
    pp_col = np.arange(70, dtype=np.int64) % 10       # 70 pps, 10 distinct
    row_nnz, _ = hash_dedup_row_nnz(pp_row, pp_col, 1, 64)
    assert row_nnz[0] == 10
    with pytest.raises(ValueError, match="overflows"):
        hash_dedup_row_nnz(np.zeros(70, np.int64),
                           np.arange(70, dtype=np.int64), 1, 64)


def test_hash_bucket_reseed_at_adversarial_stride():
    """Columns sharing a power-of-two stride (the degenerate case for
    low-k-bit hashing) still get an injective per-block bucket map, and
    every executor stays exact."""
    n_cols = 16 << 16
    ar = np.zeros(16, np.int64)
    ac = np.arange(16, dtype=np.int64)
    br = np.arange(16, dtype=np.int64)
    bc = np.arange(16, dtype=np.int64) << 16      # stride 2^16 columns
    plan = make_spgemm_plan(ar, ac, 4, br, bc, 16, n_cols)
    assert plan.nnz_out == 16
    gammas = np.asarray(plan.gammas)
    assert (gammas % 2 == 1).all()                # odd ⇒ bijective mod 2^32
    buckets = hash_bucket(np.asarray(plan.c_col), gammas[0], plan.pad_width)
    assert np.unique(buckets).size == 16          # injective on the row set
    for name in sb.ALL_SPGEMM_BACKENDS:
        np.testing.assert_allclose(
            np.asarray(sb.spgemm(plan, backend=name)), np.ones(16),
            rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Numeric phase: executor parity against the dense oracle
# ---------------------------------------------------------------------------

def _full_parity(plan, c_vals, dense_c, tol=1e-4):
    """Scatter the sparse result into dense and compare EVERYWHERE — also
    catches mass leaking off the symbolic structure."""
    got = np.zeros_like(dense_c)
    got[np.asarray(plan.c_row), np.asarray(plan.c_col)] = np.asarray(c_vals)
    np.testing.assert_allclose(got, dense_c, rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_spgemm_executor_parity_powerlaw(backend):
    from repro.data.synthetic import powerlaw_graph
    rng = np.random.default_rng(0)
    n = 200
    s, r = powerlaw_graph(n, 1200, seed=9)
    av = rng.normal(size=s.size).astype(np.float32)
    bv = rng.normal(size=s.size).astype(np.float32)
    plan = make_spgemm_plan(r, s, n, r, s, n, a_vals=av, b_vals=bv,
                            chunk=256)
    c = sb.spgemm(plan, backend=backend)
    dense_c = _dense_of(r, s, av, n, n) @ _dense_of(r, s, bv, n, n)
    _full_parity(plan, c, dense_c)
    assert plan.pp_dedup <= plan.pp_interim     # operand reuse never inflates


@pytest.mark.parametrize("backend", sb.ALL_SPGEMM_BACKENDS)
def test_spgemm_rectangular_and_value_override(backend):
    """Structure is plan state, values are data: same plan, fresh values."""
    from repro.sparse import quantize

    rng = np.random.default_rng(3)
    n, m, k = 24, 50, 9
    ar, ac, av = _coo(rng, n, m, 90)
    br, bc, bv = _coo(rng, m, k, 70)
    plan = make_spgemm_plan(ar, ac, n, br, bc, m, k, a_vals=av, b_vals=bv,
                            chunk=64)
    # the quantized executor is exact only up to its scale-derived bound
    # (tests/test_quantized.py gates that bound); f32 executors stay at 1e-4
    tol = 1e-4
    if backend == "pallas_q8":
        tol = 1.01 * float(quantize.spgemm_q8_bound(
            plan.width, plan.ell_out_block, plan.n_blocks,
            plan.ell_a_scale, plan.slab_scale))
    _full_parity(plan, sb.spgemm(plan, backend=backend),
                 _dense_of(ar, ac, av, n, m) @ _dense_of(br, bc, bv, m, k),
                 tol=tol)
    av2 = rng.normal(size=av.size).astype(np.float32)
    c2 = sb.spgemm(plan, jnp.asarray(av2), None, backend=backend)
    _full_parity(plan, c2,
                 _dense_of(ar, ac, av2, n, m) @ _dense_of(br, bc, bv, m, k),
                 tol=tol)


@pytest.mark.parametrize("backend", sb.ALL_SPGEMM_BACKENDS)
def test_spgemm_empty_rows_and_all_zero_output(backend):
    # disjoint support ⇒ nnz_out == 0; executors return a (0,) result
    plan0 = make_spgemm_plan(np.array([0, 1]), np.array([2, 3]), 4,
                             np.array([0, 1]), np.array([0, 1]), 4, 4)
    assert plan0.nnz_out == 0
    assert sb.spgemm(plan0, backend=backend).shape == (0,)
    # fully empty operands
    empty = np.array([], np.int64)
    plan_e = make_spgemm_plan(empty, empty, 6, empty, empty, 6, 6)
    assert plan_e.nnz_out == 0 and plan_e.pp_interim == 0
    assert sb.spgemm(plan_e, backend=backend).shape == (0,)
    # rows of A with no nnz stay empty in C
    ar = np.array([2, 2, 5], np.int64)
    ac = np.array([0, 1, 1], np.int64)
    plan_r = make_spgemm_plan(ar, ac, 8, ar, ac, 8, 8)
    assert (np.diff(np.asarray(plan_r.c_indptr))[[0, 1, 3, 4, 6, 7]] == 0
            ).all()
    c = sb.spgemm(plan_r, backend=backend)
    dense_c = (_dense_of(ar, ac, np.ones(3, np.float32), 8, 8)
               @ _dense_of(ar, ac, np.ones(3, np.float32), 8, 8))
    _full_parity(plan_r, c, dense_c)


def test_spgemm_backend_registry():
    with pytest.raises(KeyError, match="unknown spgemm backend"):
        sb.get_spgemm_backend("nope")
    with pytest.raises(ValueError, match="a_vals"):
        plan = make_spgemm_plan(np.array([0]), np.array([0]), 2,
                                np.array([0]), np.array([0]), 2, 2)
        sb.spgemm(plan, jnp.ones((5,), jnp.float32))


def test_spgemm_plan_lazy_executor_layouts():
    """executors= builds only the requested layouts; running an executor
    whose layout is missing is a loud error, never a silent zero."""
    rng = np.random.default_rng(6)
    ar, ac, av = _coo(rng, 16, 16, 40)
    ref_only = make_spgemm_plan(ar, ac, 16, ar, ac, 16, 16, a_vals=av,
                                b_vals=av, executors=("reference",))
    assert ref_only.ell_a is None and ref_only.pad_width == 0
    dense_c = _dense_of(ar, ac, av, 16, 16) @ _dense_of(ar, ac, av, 16, 16)
    _full_parity(ref_only, sb.spgemm(ref_only, backend="reference"),
                 dense_c)
    _full_parity(ref_only, sb.spgemm(ref_only, backend="dense"), dense_c)
    with pytest.raises(ValueError, match="'pallas' layout"):
        sb.spgemm(ref_only, backend="pallas")
    pallas_only = make_spgemm_plan(ar, ac, 16, ar, ac, 16, 16, a_vals=av,
                                   b_vals=av, executors=("pallas",))
    assert pallas_only.pp_a is None
    _full_parity(pallas_only, sb.spgemm(pallas_only, backend="pallas"),
                 dense_c)
    with pytest.raises(ValueError, match="'reference' layout"):
        sb.spgemm(pallas_only, backend="reference")
    with pytest.raises(KeyError, match="unknown spgemm executor"):
        make_spgemm_plan(ar, ac, 16, ar, ac, 16, 16, executors=("nope",))


# ---------------------------------------------------------------------------
# Â²-powered workloads: two-hop aggregation + graph coarsening
# ---------------------------------------------------------------------------

def test_two_hop_graph_matches_dense_square():
    from repro.data.synthetic import powerlaw_graph
    from repro.sparse.graph import make_graph
    s, r = powerlaw_graph(150, 700, seed=5)
    g = make_graph(s, r, 150)
    g2 = two_hop_graph(g, backend="pallas")
    v = np.asarray(g.edge_valid)
    a = _dense_of(np.asarray(g.receivers)[v], np.asarray(g.senders)[v],
                  np.ones(int(v.sum()), np.float32), 150, 150)
    c = a @ a
    np.fill_diagonal(c, 0.0)                     # drop_self_loops default
    v2 = np.asarray(g2.edge_valid)
    got = _dense_of(np.asarray(g2.receivers)[v2],
                    np.asarray(g2.senders)[v2],
                    np.asarray(g2.edge_weight)[v2], 150, 150)
    np.testing.assert_allclose(got, c, rtol=1e-5, atol=1e-5)


def test_coarsen_graph_matches_dense():
    from repro.data.synthetic import powerlaw_graph
    from repro.sparse.graph import coarsen_graph, make_graph
    rng = np.random.default_rng(8)
    s, r = powerlaw_graph(120, 500, seed=8)
    w = rng.normal(size=s.size).astype(np.float32)
    g = make_graph(s, r, 120, edge_weight=w)
    clusters = rng.integers(0, 7, 120)
    gc = coarsen_graph(g, clusters, 7, backend="reference")
    v = np.asarray(g.edge_valid)
    a = _dense_of(np.asarray(g.receivers)[v], np.asarray(g.senders)[v],
                  np.asarray(g.edge_weight)[v], 120, 120)
    p = np.zeros((120, 7), np.float32)
    p[np.arange(120), clusters] = 1.0
    want = p.T @ a @ p
    vc = np.asarray(gc.edge_valid)
    got = _dense_of(np.asarray(gc.receivers)[vc],
                    np.asarray(gc.senders)[vc],
                    np.asarray(gc.edge_weight)[vc], 7, 7)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_gin_two_hop_trains_through_steps(backend):
    """Acceptance: two_hop mode trains GIN end-to-end through
    launch/steps.py — Â² precomputed once by the SpGEMM engine, every step
    plain SpMM on its plan; the loss must strictly decrease."""
    from repro.data.synthetic import powerlaw_graph
    from repro.launch import steps as steps_mod
    from repro.models.gnn import gin
    from repro.optim import adamw
    from repro.sparse.graph import make_graph
    s, r = powerlaw_graph(80, 320, seed=6)
    g = make_graph(s, r, 80)
    cfg = gin.GINConfig(d_in=6, d_hidden=12, n_classes=2, n_layers=2,
                        two_hop=True)
    params = gin.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    n_pad = 81
    batch = {
        "x": jnp.asarray(rng.normal(size=(n_pad, 6)).astype(np.float32)),
        "senders": g.senders, "receivers": g.receivers,
        "edge_valid": g.edge_valid,
        "graph_ids": jnp.asarray((np.arange(n_pad) % 2).astype(np.int32)),
        "labels": jnp.asarray(np.array([0, 1], np.int32)),
    }
    step = jax.jit(steps_mod.build_gnn_step(
        "gin", cfg, None, {"n_graphs": 2}, adamw.AdamWConfig(lr=1e-3),
        backend=backend, graph=g))
    opt = adamw.init_state(params)
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_gin_two_hop_parity_across_backends():
    """The same two-hop step must produce identical losses on every local
    executor (the acceptance 1e-4 band)."""
    from repro.data.synthetic import powerlaw_graph
    from repro.launch import steps as steps_mod
    from repro.models.gnn import gin
    from repro.optim import adamw
    from repro.sparse.graph import make_graph
    s, r = powerlaw_graph(60, 240, seed=2)
    g = make_graph(s, r, 60)
    cfg = gin.GINConfig(d_in=5, d_hidden=8, n_classes=2, n_layers=2,
                        two_hop=True)
    params = gin.init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(2)
    batch = {
        "x": jnp.asarray(rng.normal(size=(61, 5)).astype(np.float32)),
        "senders": g.senders, "receivers": g.receivers,
        "edge_valid": g.edge_valid,
        "graph_ids": jnp.asarray(np.zeros(61, np.int32)),
        "labels": jnp.asarray(np.array([1], np.int32)),
    }
    losses = {}
    for backend in ("dense", "chunked", "pallas"):
        step = jax.jit(steps_mod.build_gnn_step(
            "gin", cfg, None, {"n_graphs": 1}, adamw.AdamWConfig(lr=1e-3),
            backend=backend, graph=g))
        _, _, m = step(params, adamw.init_state(params), batch)
        losses[backend] = float(m["loss"])
    ref = losses["dense"]
    for backend, loss in losses.items():
        assert abs(loss - ref) < 1e-4, (backend, losses)


def test_two_hop_rejected_for_edge_value_models():
    from repro.launch import steps as steps_mod
    with pytest.raises(ValueError, match="two_hop"):
        steps_mod.build_gnn_step("gat-cora", object(), None,
                                 {"n_graphs": 1}, backend="dense",
                                 two_hop=True)


def test_two_hop_never_degrades_silently():
    """two_hop without a graph (or with an explicit one-hop plan) must be a
    loud error, never a silent fall-back to one-hop aggregation."""
    from repro.data.synthetic import powerlaw_graph
    from repro.launch import steps as steps_mod
    from repro.models.gnn import gin
    from repro.sparse.graph import make_graph
    from repro.sparse.plan import plan_from_graph
    cfg = gin.GINConfig(two_hop=True)
    with pytest.raises(ValueError, match="needs graph"):
        steps_mod.build_gnn_step("gin", cfg, None, {"n_graphs": 1},
                                 backend="dense")
    s, r = powerlaw_graph(30, 90, seed=0)
    g = make_graph(s, r, 30)
    with pytest.raises(ValueError, match="not plan"):
        steps_mod.build_gnn_step("gin", cfg, None, {"n_graphs": 1},
                                 backend="dense", graph=g,
                                 plan=plan_from_graph(g))


def test_dimenet_two_hop_through_steps():
    """DimeNet's two_hop config routes the Â² plan into the output block."""
    import dataclasses as dc
    from repro.configs.dimenet import reduced
    from repro.launch import steps as steps_mod
    from repro.models.gnn import dimenet
    from repro.optim import adamw
    from repro.sparse.graph import make_graph
    rng = np.random.default_rng(4)
    n, e = 16, 40
    s = rng.integers(0, n, e).astype(np.int32)
    r = (s + 1 + rng.integers(0, n - 1, e).astype(np.int32)) % n
    g = make_graph(s, r, n, pad_multiple=8)
    e_pad = np.asarray(g.senders).shape[0]
    t = e_pad * 2
    batch = {
        "species": jnp.asarray(rng.integers(1, 5, n + 1).astype(np.int32)),
        "pos": jnp.asarray(rng.normal(size=(n + 1, 3)).astype(np.float32)),
        "senders": g.senders, "receivers": g.receivers,
        "edge_valid": g.edge_valid,
        "t_in": jnp.asarray(rng.integers(0, e_pad, t).astype(np.int32)),
        "t_out": jnp.asarray(rng.integers(0, e_pad, t).astype(np.int32)),
        "t_valid": jnp.asarray(np.ones(t, bool)),
        "graph_ids": jnp.asarray(np.zeros(n + 1, np.int32)),
        "targets": jnp.asarray(np.array([0.5], np.float32)),
    }
    losses = {}
    # (cfg.two_hop, explicit two_hop arg): the arg must win over the config
    for case, (cfg_flag, arg) in {"off": (False, None), "cfg": (True, None),
                                  "arg": (False, True)}.items():
        cfg = dc.replace(reduced(), two_hop=cfg_flag)
        step = jax.jit(steps_mod.build_gnn_step(
            "dimenet", cfg, None, {"n_graphs": 1}, adamw.AdamWConfig(),
            backend="dense", graph=g, two_hop=arg))
        params = dimenet.init_params(jax.random.key(0), cfg)
        _, _, m = step(params, adamw.init_state(params), batch)
        losses[case] = float(m["loss"])
    assert all(np.isfinite(v) for v in losses.values())
    assert losses["cfg"] != losses["off"]   # the Â² stage actually fires
    assert losses["arg"] == losses["cfg"]   # explicit arg never a no-op
