"""Fault-tolerant serving control plane (DESIGN.md §13) under deterministic
chaos injection.

The contract every test here closes over: an accepted request settles
**exactly once** — a result XOR a typed ``serve.errors`` error; never lost,
never duplicated — no matter which lane dies, which worker throws, or which
device step transiently fails.  All cluster tests run replicated/stacked
(device-count agnostic → tier-1 safe); timing knobs are sized so each
scenario converges in well under its drain timeout on a loaded CI box.
"""
import threading
import time

import numpy as np
import pytest

from repro.launch.gnn_serve import build_world
from repro.serve import (ChaosInjector, ClusterServer, GNNServer,
                         InjectedSamplerFault, LaneFault)
from repro.serve.errors import (DeadlineExceeded, DrainTimeout, Overloaded,
                                RetriesExhausted, SamplerError, ServerClosed)

N = 4                                     # lanes in every cluster test


def _world(arch="sage", n_nodes=256, seed=0):
    return build_world(arch, n_nodes, 4 * n_nodes, 8, seed=seed)


def _cluster(world, chaos=None, **kw):
    cfg, params, indptr, indices, store = world
    kw.setdefault("n_lanes", N)
    kw.setdefault("fanouts", (2, 2))
    kw.setdefault("backend", "dense")
    kw.setdefault("seed", 0)
    kw.setdefault("max_batch_seeds", 4)
    kw.setdefault("telemetry_interval", 0.02)
    return ClusterServer("sage", cfg, params, indptr, indices, store,
                         chaos=chaos, **kw)


def _assert_exactly_once(reqs, expect_error=None):
    for r in reqs:
        assert r.done, f"request {r.rid} never settled"
        assert r.n_settles == 1, f"request {r.rid} settled {r.n_settles}×"
        if expect_error is None:
            assert r.error is None, f"request {r.rid} failed: {r.error!r}"
            assert r.result is not None
        else:
            assert isinstance(r.error, expect_error), \
                f"request {r.rid}: {r.error!r}"
            assert r.result is None


# ---------------------------------------------------------------------------
# Injector determinism (pure host logic)
# ---------------------------------------------------------------------------

def test_injector_is_deterministic_and_validates():
    a = ChaosInjector(seed=7, p_step_fault=0.3, p_sampler_fault=0.2)
    b = ChaosInjector(seed=7, p_step_fault=0.3, p_sampler_fault=0.2)
    assert ([a.step_fault(r) for r in range(200)]
            == [b.step_fault(r) for r in range(200)])
    assert any(a.step_fault(r) for r in range(200))
    c = ChaosInjector(seed=8, p_step_fault=0.3)
    assert ([a.step_fault(r) for r in range(200)]
            != [c.step_fault(r) for r in range(200)])
    with pytest.raises(ValueError, match="lane-fault kind"):
        LaneFault(lane=0, kind="meteor")


def test_injector_scripted_faults_fire_exactly_where_scheduled():
    ch = ChaosInjector(step_fault_rounds=(3, 5))
    assert [ch.step_fault(r) for r in range(1, 7)] == \
        [False, False, True, False, True, False]

    class R:
        rid = 9
    ch2 = ChaosInjector(sampler_fault_rids=(9,))
    with pytest.raises(InjectedSamplerFault):
        ch2.sampler_hook(R())
    assert ch2.injected["sampler"] == 1


def test_kill_blocks_until_acknowledged_then_spent():
    ch = ChaosInjector(lane_faults=[LaneFault(lane=1, at_round=2)])
    assert not ch.blocked(1, 1)           # not yet at the trigger round
    assert ch.blocked(1, 2)               # fires
    assert ch.blocked(1, 5)               # stays wedged (a crash, not a GC)
    assert not ch.blocked(0, 5)           # other lanes unaffected
    ch.on_lane_dead(1)                    # supervisor declared it dead
    assert not ch.blocked(1, 6)           # the restarted lane is fresh
    assert ch.injected["kill"] == 1


def test_stall_self_recovers_after_duration():
    t = {"now": 0.0}
    ch = ChaosInjector(lane_faults=[LaneFault(lane=0, kind="stall",
                                              duration=1.0)],
                       clock=lambda: t["now"])
    assert ch.blocked(0, 0)
    t["now"] = 0.5
    assert ch.blocked(0, 3)
    t["now"] = 1.5
    assert not ch.blocked(0, 4)           # elapsed: lane is back


# ---------------------------------------------------------------------------
# Tentpole scenario: lane kill mid-stream → exactly-once, zero lost
# ---------------------------------------------------------------------------

def test_lane_kill_mid_stream_every_request_exactly_once():
    """Kill 1 of 4 lanes mid-stream.  The supervisor must detect the death,
    rebalance the router onto the 3 survivors, re-route the dead lane's
    backlog exactly once, and every request must settle with a result —
    zero lost, zero duplicated."""
    chaos = ChaosInjector(lane_faults=[LaneFault(lane=1, at_round=3)])
    srv = _cluster(_world(), chaos=chaos, stall_timeout=0.15,
                   auto_restart=False)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(192)])
        srv.drain(timeout=120)
        _assert_exactly_once(reqs)
        assert chaos.injected["kill"] == 1          # the fault actually fired
        st = srv.stats()
        assert st["lane_deaths"] == 1
        assert st["n_served"] == len(reqs)
        # survivors-only routing, and the backlog re-routed exactly once
        assert srv.router.n_active == N - 1
        assert 1 not in srv.router.active_lanes
        assert srv.lane_states()[1] == "dead"
        assert st["reroutes"] > 0
        assert all(r.reroutes <= 1 for r in reqs)   # never bounced twice
        rerouted = [r for r in reqs if r.reroutes == 1]
        assert len(rerouted) == st["reroutes"]
        assert all(r.lane != 1 for r in rerouted)
        # parity survives failover: re-routed results still match offline
        for r in rerouted[:4]:
            np.testing.assert_allclose(r.result, srv.offline_replay(r),
                                       atol=1e-5)


def test_killed_lane_restarts_and_rejoins():
    """After ``restart_after`` the supervisor shadow-warms the dead lane and
    rebalances it back in; a second burst serves on all 4 lanes."""
    chaos = ChaosInjector(lane_faults=[LaneFault(lane=2, at_round=2)])
    srv = _cluster(_world(), chaos=chaos, stall_timeout=0.15,
                   restart_after=0.2, auto_restart=True)
    with srv:
        srv.warmup()
        first = srv.submit_many([[i % 256] for i in range(128)])
        srv.drain(timeout=120)
        _assert_exactly_once(first)
        deadline = time.monotonic() + 30
        while srv.router.n_active < N and time.monotonic() < deadline:
            time.sleep(0.02)
        assert srv.router.n_active == N, srv.lane_states()
        assert srv.lane_states() == ["active"] * N
        second = srv.submit_many([[(3 * i) % 256] for i in range(64)])
        srv.drain(timeout=120)
        _assert_exactly_once(second)
        st = srv.stats()
        assert st["lane_deaths"] == 1 and st["lane_restores"] == 1
        assert st["n_served"] == len(first) + len(second)


def test_stall_shorter_than_timeout_is_tolerated():
    """A GC-pause-sized stall (shorter than the supervisor's stall timeout)
    must NOT be treated as a death — the lane resumes by itself."""
    chaos = ChaosInjector(lane_faults=[LaneFault(lane=0, at_round=1,
                                                 kind="stall",
                                                 duration=0.1)])
    srv = _cluster(_world(), chaos=chaos, stall_timeout=2.0)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(96)])
        srv.drain(timeout=120)
        _assert_exactly_once(reqs)
        st = srv.stats()
        assert st["lane_deaths"] == 0 and st["reroutes"] == 0
        assert srv.router.n_active == N


# ---------------------------------------------------------------------------
# Sampler-worker faults: typed, isolated, non-wedging (satellite audit)
# ---------------------------------------------------------------------------

def test_cluster_sampler_fault_fails_only_that_request():
    chaos = ChaosInjector(sampler_fault_rids=(5,))
    srv = _cluster(_world(), chaos=chaos)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(16)])
        srv.drain(timeout=120)
        bad = [r for r in reqs if r.rid == 5]
        good = [r for r in reqs if r.rid != 5]
        _assert_exactly_once(bad, expect_error=SamplerError)
        _assert_exactly_once(good)
        assert bad[0].error.rid == 5                # typed, carries the rid
        assert isinstance(bad[0].error.__cause__, InjectedSamplerFault)
        # neither the worker nor the engine loop wedged: keep serving
        more = srv.submit_many([[i % 256] for i in range(16)])
        srv.drain(timeout=120)
        _assert_exactly_once(more)
        assert srv.stats()["failed"] == 1


def test_gnn_server_sampler_fault_isolated_and_typed():
    cfg, params, indptr, indices, store = _world()
    chaos = ChaosInjector(sampler_fault_rids=(2,))
    srv = GNNServer("sage", cfg, params, indptr, indices, store,
                    fanouts=(2, 2), backend="dense", chaos=chaos,
                    max_batch_seeds=4)
    with srv:
        reqs = [srv.submit([i % 256]) for i in range(8)]
        srv.drain(timeout=120)
        bad = [r for r in reqs if r.rid == 2]
        _assert_exactly_once(bad, expect_error=SamplerError)
        _assert_exactly_once([r for r in reqs if r.rid != 2])
        assert bad[0].error.rid == 2
        more = [srv.submit([i % 256]) for i in range(8)]
        srv.drain(timeout=120)
        _assert_exactly_once(more)


# ---------------------------------------------------------------------------
# Transient step faults: retry-once, then typed exhaustion
# ---------------------------------------------------------------------------

def test_transient_step_fault_retried_and_served():
    chaos = ChaosInjector(step_fault_rounds=(1,))
    srv = _cluster(_world(), chaos=chaos, max_retries=1)
    with srv:
        srv.warmup()
        reqs = srv.submit_many([[i % 256] for i in range(48)])
        srv.drain(timeout=120)
        _assert_exactly_once(reqs)
        st = srv.stats()
        assert chaos.injected["step"] >= 1
        assert st["retries"] > 0 and st["failed"] == 0


def test_every_step_faulting_exhausts_retries_typed():
    chaos = ChaosInjector(p_step_fault=1.0)
    srv = _cluster(_world(), chaos=chaos, max_retries=1)
    with srv:
        reqs = srv.submit_many([[i % 256] for i in range(16)])
        srv.drain(timeout=120)
        _assert_exactly_once(reqs, expect_error=RetriesExhausted)
        assert all(r.attempts == 2 for r in reqs)   # 1 try + 1 retry


# ---------------------------------------------------------------------------
# Deadlines, shedding, drain/close (satellites)
# ---------------------------------------------------------------------------

def _all_lanes_wedged():
    return ChaosInjector(lane_faults=[LaneFault(lane=i) for i in range(N)])


def test_deadline_exceeded_is_typed_and_reaped():
    """Every lane wedged + a 100 ms deadline: the batcher must reap every
    request with ``DeadlineExceeded`` instead of leaving it queued."""
    srv = _cluster(_world(), chaos=_all_lanes_wedged(), stall_timeout=60)
    with srv:
        reqs = srv.submit_many([[i % 256] for i in range(24)],
                               deadline_ms=100)
        srv.drain(timeout=60)
        _assert_exactly_once(reqs, expect_error=DeadlineExceeded)
        assert all(isinstance(r.error, TimeoutError) for r in reqs)
        assert srv.stats()["timeouts"] == len(reqs)


def test_sustained_overload_sheds_at_submit():
    """Wedge every lane so the queue only grows: after the sustain window
    the server must reject new work with typed ``Overloaded`` backpressure;
    already-accepted requests still settle at close."""
    srv = _cluster(_world(), chaos=_all_lanes_wedged(), stall_timeout=60,
                   shed_queue_hwm=8, shed_sustain_ticks=1)
    accepted = srv.submit_many([[i % 256] for i in range(32)])
    deadline = time.monotonic() + 10
    while not srv._shedding and time.monotonic() < deadline:
        time.sleep(0.01)
    with pytest.raises(Overloaded) as ei:
        srv.submit([0])
    assert ei.value.retry_after_s > 0
    assert srv.stats()["shed"] >= 1
    srv.close()                            # shutdown flush serves the backlog
    _assert_exactly_once(accepted)


def test_drain_timeout_fails_stragglers_typed_then_close_is_safe():
    """Satellite 1: a drain deadline must FAIL the stragglers with
    ``DrainTimeout`` (count surfaced), not leave them silently pending; the
    follow-up close must not double-settle them, and close is idempotent."""
    srv = _cluster(_world(), chaos=_all_lanes_wedged(), stall_timeout=60)
    reqs = srv.submit_many([[i % 256] for i in range(8)])
    with pytest.raises(DrainTimeout) as ei:
        srv.drain(timeout=0.3)
    assert ei.value.n_pending == len(reqs)
    assert sorted(ei.value.rids) == sorted(r.rid for r in reqs)
    _assert_exactly_once(reqs, expect_error=DrainTimeout)
    srv.close()        # flush serves the already-failed stragglers: no-op
    srv.close()        # idempotent
    _assert_exactly_once(reqs, expect_error=DrainTimeout)


def test_close_times_out_over_wedged_engine_and_fails_pending():
    """Satellite 1: ``close`` over a wedged engine loop must return within
    its timeout and fail still-pending requests with ``ServerClosed`` —
    never hang the caller."""
    srv = _cluster(_world(), stall_timeout=60)
    wedge = threading.Event()              # never set: the daemon thread
    srv._gather = lambda node_ids: wedge.wait()    # stays parked until exit
    reqs = srv.submit_many([[i % 256] for i in range(4)])
    t0 = time.monotonic()
    srv.close(timeout=0.5)
    assert time.monotonic() - t0 < 5.0
    _assert_exactly_once(reqs, expect_error=ServerClosed)
    srv.close(timeout=0.5)                 # idempotent over the wedge too


# ---------------------------------------------------------------------------
# Elastic scaling (telemetry-driven park/unpark)
# ---------------------------------------------------------------------------

def test_elastic_parks_idle_lanes_and_unparks_under_load():
    chaos = ChaosInjector(lane_faults=[
        LaneFault(lane=0, at_round=1, kind="stall", duration=0.6),
        LaneFault(lane=1, at_round=1, kind="stall", duration=0.6)])
    srv = _cluster(_world(), chaos=chaos, stall_timeout=30,
                   scale_min_lanes=2, scale_down_depth=0.5,
                   scale_up_depth=1.0, scale_sustain_ticks=2)
    with srv:
        srv.warmup()
        deadline = time.monotonic() + 30
        while (srv.lane_states().count("parked") < N - 2
               and time.monotonic() < deadline):
            time.sleep(0.02)               # idle: scale down to the floor
        assert srv.lane_states().count("parked") == N - 2
        assert srv.router.n_active == 2
        reqs = srv.submit_many([[i % 256] for i in range(64)])
        srv.drain(timeout=120)             # stalls elapse; burst drains
        _assert_exactly_once(reqs)
        ev = srv.telemetry.event_counts()
        assert ev.get("scale_down", 0) >= 2
        assert ev.get("scale_up", 0) >= 1  # load pulled a lane back in
