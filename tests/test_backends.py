"""Unified sparse-backend engine: executor parity, plan contracts, registry.

Satellite coverage for the backend refactor: ``dense``, ``chunked`` and
``pallas`` (interpret mode) must agree within 1e-4 on GCN/GAT/SAGE forward
passes over random graphs, including empty-row and all-padding edge cases;
``distributed`` parity runs in a subprocess over 8 emulated devices.
"""
import functools
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import backend as sb
from repro.sparse.plan import (ALL_BACKENDS, BackendPlanError, edge_plan,
                               make_plan)

PARITY_BACKENDS = ("chunked", "pallas")


def _random_plan_inputs(n, e, seed, weighted=True, n_invalid=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    w = rng.normal(size=e).astype(np.float32) if weighted else None
    valid = np.ones(e, bool)
    if n_invalid:
        valid[e - n_invalid:] = False
    return s, r, w, valid, rng


# ---------------------------------------------------------------------------
# Raw aggregate parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("weighted", [True, False])
def test_aggregate_parity(backend, weighted):
    n, e, d = 64, 400, 24
    s, r, w, valid, rng = _random_plan_inputs(n, e, 0, weighted, n_invalid=60)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    plan = make_plan(s, r, n, edge_weight=w, edge_valid=valid,
                     backends=("dense", "chunked", "pallas"), chunk=128)
    ref = sb.aggregate(plan, None, x, backend="dense")
    out = sb.aggregate(plan, None, x, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_aggregate_traced_vals_parity(backend):
    """Traced per-edge values (the GAT-attention path) route through the
    plan's scatter slots on every executor."""
    n, e, d = 48, 256, 16
    s, r, _, valid, rng = _random_plan_inputs(n, e, 1, False, n_invalid=32)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    vals = jnp.asarray(rng.normal(size=e).astype(np.float32))
    plan = make_plan(s, r, n, edge_valid=valid,
                     backends=("dense", "chunked", "pallas"), chunk=64)

    @functools.partial(jax.jit, static_argnames=("nm",))
    def agg(v, xx, nm):
        return sb.aggregate(plan, v, xx, backend=nm)

    ref = agg(vals, x, "dense")
    out = agg(vals, x, backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_aggregate_empty_rows_and_all_padding():
    """Nodes with no in-edges get zeros; an all-padding edge list yields an
    all-zero result on every local executor."""
    n, e, d = 40, 96, 8
    rng = np.random.default_rng(3)
    s = rng.integers(0, 4, e)          # only rows 0..3 ever receive
    r = rng.integers(0, 4, e)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    plan = make_plan(s, r, n, backends=("dense", "chunked", "pallas"),
                     chunk=32)
    ref = sb.aggregate(plan, None, x, backend="dense")
    assert float(jnp.abs(ref[4:]).max()) == 0.0
    for backend in PARITY_BACKENDS:
        out = sb.aggregate(plan, None, x, backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    all_pad = make_plan(s, r, n, edge_valid=np.zeros(e, bool),
                        backends=("dense", "chunked", "pallas"), chunk=32)
    for backend in ("dense",) + PARITY_BACKENDS:
        out = sb.aggregate(all_pad, None, x, backend=backend)
        assert float(jnp.abs(out).max()) == 0.0, backend


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_accumulate_parity(backend):
    n, e, d = 32, 200, 12
    s, r, _, valid, rng = _random_plan_inputs(n, e, 5, False, n_invalid=40)
    msgs = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32))
    plan = make_plan(s, r, n, edge_valid=valid,
                     backends=("dense", "chunked", "pallas"), chunk=64)
    ref = sb.accumulate(plan, msgs, backend="dense")
    out = sb.accumulate(plan, msgs, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Model-level parity: GCN / GAT / SAGE forward passes
# ---------------------------------------------------------------------------

def _graph_and_plan(n, e, seed, weighted, n_invalid=0):
    from repro.sparse.graph import sym_norm_weights
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    if weighted:
        s, r, w = sym_norm_weights(s, r, n)
    else:
        w = None
    e_tot = s.shape[0]
    valid = np.ones(e_tot, bool)
    if n_invalid:
        valid[e_tot - n_invalid:] = False
    plan = make_plan(s, r, n + 1, edge_weight=w, edge_valid=valid,
                     backends=("dense", "chunked", "pallas"), chunk=128)
    return rng, plan


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("n_invalid", [0, 37])
def test_gcn_forward_backend_parity(backend, n_invalid):
    from repro.models.gnn import gcn
    cfg = gcn.GCNConfig(d_in=12, d_hidden=8, n_classes=5, n_layers=2)
    rng, plan = _graph_and_plan(50, 200, 0, True, n_invalid)
    x = jnp.asarray(rng.normal(size=(51, cfg.d_in)).astype(np.float32))
    params = gcn.init_params(jax.random.key(0), cfg)
    ref = gcn.forward(params, cfg, x, backend="dense", plan=plan)
    out = gcn.forward(params, cfg, x, backend=backend, plan=plan)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_gat_forward_backend_parity(backend):
    from repro.models.gnn import gat
    cfg = gat.GATConfig(d_in=10, d_hidden=4, n_heads=2, n_classes=3,
                        n_layers=2)
    rng, plan = _graph_and_plan(40, 150, 1, False, n_invalid=20)
    x = jnp.asarray(rng.normal(size=(41, cfg.d_in)).astype(np.float32))
    params = gat.init_params(jax.random.key(0), cfg)
    ref = gat.forward(params, cfg, x, backend="dense", plan=plan)
    out = gat.forward(params, cfg, x, backend=backend, plan=plan)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_sage_forward_backend_parity(backend):
    from repro.models.gnn import sage
    cfg = sage.SAGEConfig(d_in=8, d_hidden=6, n_classes=4, n_layers=2)
    rng, plan = _graph_and_plan(36, 120, 2, False, n_invalid=16)
    x = jnp.asarray(rng.normal(size=(37, cfg.d_in)).astype(np.float32))
    params = sage.init_params(jax.random.key(0), cfg)
    ref = sage.forward(params, cfg, x, backend="dense", plan=plan)
    out = sage.forward(params, cfg, x, backend=backend, plan=plan)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_gcn_pallas_gradients_flow():
    """The pallas executor carries a custom VJP — training must work."""
    from repro.models.gnn import gcn
    cfg = gcn.GCNConfig(d_in=6, d_hidden=4, n_classes=3, n_layers=2)
    rng, plan = _graph_and_plan(30, 100, 4, True)
    x = jnp.asarray(rng.normal(size=(31, cfg.d_in)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 3, 31), jnp.int32)
    mask = jnp.asarray(np.arange(31) < 20)
    params = gcn.init_params(jax.random.key(1), cfg)
    loss_d, grads_d = jax.value_and_grad(gcn.loss_fn)(
        params, cfg, x, None, None, None, None, labels, mask,
        backend="dense", plan=plan)
    loss_p, grads_p = jax.value_and_grad(gcn.loss_fn)(
        params, cfg, x, None, None, None, None, labels, mask,
        backend="pallas", plan=plan)
    np.testing.assert_allclose(float(loss_p), float(loss_d), rtol=1e-4)
    for gd, gp in zip(jax.tree.leaves(grads_d), jax.tree.leaves(grads_p)):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gd),
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("backend", ("chunked", "pallas"))
def test_gin_schnet_dimenet_accept_backend(backend):
    """The remaining models route through the registry too (accumulate-only
    for the vector-valued multiply stages of schnet/dimenet)."""
    from repro.models.gnn import dimenet, gin, schnet
    from repro.sparse import triplets as tri
    rng = np.random.default_rng(0)
    n, e = 30, 90
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    valid = np.ones(e, bool)

    cfg = gin.GINConfig(d_in=6, d_hidden=8, n_classes=3, n_layers=2)
    params = gin.init_params(jax.random.key(0), cfg)
    x = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    plan = make_plan(s, r, n, backends=("dense", "chunked", "pallas"),
                     chunk=32)
    ref = gin.forward(params, cfg, x, backend="dense", plan=plan)
    out = gin.forward(params, cfg, x, backend=backend, plan=plan)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    scfg = schnet.SchNetConfig(d_hidden=8, n_rbf=16, n_interactions=2)
    sparams = schnet.init_params(jax.random.key(1), scfg)
    species = jnp.asarray(rng.integers(0, 10, n), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    gid = jnp.zeros(n, jnp.int32)
    sv, rv, vv = jnp.asarray(s), jnp.asarray(r), jnp.asarray(valid)
    e_ref = schnet.forward(sparams, scfg, species, pos, sv, rv, vv, gid, 1,
                           backend="dense")
    e_out = schnet.forward(sparams, scfg, species, pos, sv, rv, vv, gid, 1,
                           backend=backend)
    np.testing.assert_allclose(np.asarray(e_out), np.asarray(e_ref),
                               rtol=1e-4, atol=1e-4)

    dcfg = dimenet.DimeNetConfig(n_blocks=1, d_hidden=8, n_bilinear=2,
                                 n_spherical=3, n_radial=2,
                                 max_triplets_per_edge=4)
    dparams = dimenet.init_params(jax.random.key(2), dcfg)
    t_in, t_out, t_val = tri.build_triplets(s, r, dcfg.max_triplets_per_edge)
    d_ref = dimenet.forward(dparams, dcfg, species, pos, sv, rv, vv,
                            jnp.asarray(t_in), jnp.asarray(t_out),
                            jnp.asarray(t_val), gid, 1, backend="dense")
    d_out = dimenet.forward(dparams, dcfg, species, pos, sv, rv, vv,
                            jnp.asarray(t_in), jnp.asarray(t_out),
                            jnp.asarray(t_val), gid, 1, backend=backend)
    np.testing.assert_allclose(np.asarray(d_out), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas kernel layout edge cases: feature tiling, DMA waves, chunk splits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [48, 96, 200])
def test_pallas_feature_tiling_non_divisible(d):
    """D not a multiple of the feature tile: the kernel pads to whole tiles
    and slices back."""
    n, e = 48, 300
    s, r, w, valid, rng = _random_plan_inputs(n, e, d, n_invalid=20)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    plan = make_plan(s, r, n, edge_weight=w, edge_valid=valid,
                     backends=("dense", "pallas"), d_tile=64)
    ref = sb.aggregate(plan, None, x, backend="dense")
    out = sb.aggregate(plan, None, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("group", [5, 7, 16])
def test_pallas_dma_group_not_dividing_width(group):
    """DMA-wave width not dividing the chunk width: the kernel lane-pads.
    Exercised in explicit-DMA gather mode, where waves matter."""
    from repro.kernels.gustavson_spmm.gustavson_spmm import spmm_dedup_chunks
    from repro.sparse.graph import pack_dedup_chunks
    n, e, d = 40, 220, 24
    rng = np.random.default_rng(group)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    vals = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ch = pack_dedup_chunks(r, s, vals, n, n)
    assert ch.width % group or group == 16
    plan = make_plan(s, r, n, edge_weight=vals, backends=("dense",))
    ref = sb.aggregate(plan, None, x, backend="dense")
    for gather in ("dma", "stream"):
        out = spmm_dedup_chunks(
            jnp.asarray(ch.u_cols), jnp.asarray(ch.remaining),
            jnp.asarray(ch.out_block), jnp.asarray(ch.first),
            jnp.asarray(ch.a), x, block_rows=ch.block_rows,
            n_blocks=ch.n_blocks, group=group, gather=gather)
        np.testing.assert_allclose(np.asarray(out[:n]), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=gather)


def test_pallas_chunk_split_hub_rows():
    """A hub receiver forces width_cap chunk splits: later chunks revisit
    their output block and accumulate into the resident tile."""
    n, e, d = 64, 600, 16
    rng = np.random.default_rng(11)
    s = rng.integers(0, n, e)
    r = np.where(rng.random(e) < 0.5, 3, rng.integers(0, n, e))  # hub row 3
    w = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    plan = make_plan(s, r, n, edge_weight=w,
                     backends=("dense", "pallas"), width_cap=16)
    assert plan.ell_u_cols.shape[0] > plan.n_blocks  # really split
    ref = sb.aggregate(plan, None, x, backend="dense")
    out = sb.aggregate(plan, None, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_pallas_empty_blocks_evict_zeros():
    """Blocks with zero nnz still evict (zero) tiles — remaining == 0."""
    n, d = 64, 8
    s = np.array([1, 2, 3])
    r = np.array([0, 0, 1])           # only block 0 receives
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d))
                    .astype(np.float32))
    plan = make_plan(s, r, n, backends=("dense", "pallas"))
    assert int(np.asarray(plan.ell_remaining).min()) == 0
    out = sb.aggregate(plan, None, x, backend="pallas")
    assert float(jnp.abs(out[2:]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x[1] + x[2]),
                               rtol=1e-5, atol=1e-5)


def test_pallas_vjp_matches_dense_autodiff():
    """Custom-VJP cotangents for BOTH `vals` and `x` match dense autodiff;
    the backward runs through the Pallas kernel, not a segment reduction."""
    import inspect
    from repro.kernels.gustavson_spmm import ops as gops
    n, e, d = 40, 250, 12
    s, r, w, valid, rng = _random_plan_inputs(n, e, 9, n_invalid=30)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    vals = jnp.asarray(w)
    plan = make_plan(s, r, n, edge_valid=valid,
                     backends=("dense", "chunked", "pallas"))

    def loss(v, xx, nm):
        y = sb.aggregate(plan, v, xx, backend=nm)
        return jnp.mean(y ** 2) + jnp.sum(y[:, 0])

    gv_d, gx_d = jax.grad(loss, argnums=(0, 1))(vals, x, "dense")
    gv_p, gx_p = jax.jit(jax.grad(loss, argnums=(0, 1)),
                         static_argnums=2)(vals, x, "pallas")
    np.testing.assert_allclose(np.asarray(gv_p), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-5)
    # the acceptance contract: no plain-JAX segment reduction in the bwd
    assert "segment_sum" not in inspect.getsource(gops._ad_bwd)


def test_pallas_bf16_stays_bf16():
    """bf16 features are not upcast: output dtype bf16, f32 accumulation."""
    n, e, d = 32, 180, 16
    s, r, w, valid, rng = _random_plan_inputs(n, e, 13)
    xf = rng.normal(size=(n, d)).astype(np.float32)
    x16 = jnp.asarray(xf, jnp.bfloat16)
    plan = make_plan(s, r, n, edge_weight=w,
                     backends=("dense", "pallas"))
    out = sb.aggregate(plan, None, x16, backend="pallas")
    assert out.dtype == jnp.bfloat16
    ref = sb.aggregate(plan, None, jnp.asarray(xf), backend="dense")
    np.testing.assert_allclose(np.float32(out), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hits_and_eviction():
    from repro.sparse import plan as plan_mod
    from repro.sparse.graph import make_graph
    plan_mod.plan_cache_clear()
    rng = np.random.default_rng(0)
    graphs = [make_graph(rng.integers(0, 24, 60), rng.integers(0, 24, 60), 24)
              for _ in range(3)]
    p1 = plan_mod.cached_plan_from_graph(graphs[0], backends=("pallas",))
    p2 = plan_mod.cached_plan_from_graph(graphs[0], backends=("pallas",))
    assert p1 is p2                                     # identity hit
    info = plan_mod.plan_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1
    # different layout params → different entry
    p3 = plan_mod.cached_plan_from_graph(graphs[0], backends=("pallas",),
                                         block_rows=16)
    assert p3 is not p1
    # LRU eviction at maxsize
    for g in graphs:
        plan_mod.cached_plan_from_graph(g, backends=("dense",), maxsize=2)
    assert plan_mod.plan_cache_info()["size"] <= 2
    p4 = plan_mod.cached_plan_from_graph(graphs[0], backends=("dense",),
                                         maxsize=2)   # was evicted → repack
    assert isinstance(p4, plan_mod.AggregationPlan)
    plan_mod.plan_cache_clear()
    assert plan_mod.plan_cache_info() == {"hits": 0, "misses": 0, "size": 0}


def test_plan_cache_used_by_step_builder():
    from repro.launch import steps as steps_mod
    from repro.sparse import plan as plan_mod
    from repro.sparse.graph import make_graph
    plan_mod.plan_cache_clear()
    rng = np.random.default_rng(1)
    g = make_graph(rng.integers(0, 16, 40), rng.integers(0, 16, 40), 16)
    a = steps_mod.resolve_gnn_plan(g, "pallas")
    b = steps_mod.resolve_gnn_plan(g, "pallas")
    assert a is b and a.has("ell")
    assert steps_mod.resolve_gnn_plan(g, "dense") is None
    plan_mod.plan_cache_clear()


# ---------------------------------------------------------------------------
# Plan / registry contracts
# ---------------------------------------------------------------------------

def test_chunked_autopads_indivisible_edge_counts():
    """spmm_chunked no longer asserts on E % chunk != 0."""
    from repro.core import spgemm
    rng = np.random.default_rng(0)
    n, e, d = 40, 300, 8                       # 300 % 128 != 0
    rows = jnp.asarray(rng.integers(0, n, e))
    cols = jnp.asarray(rng.integers(0, n, e))
    vals = jnp.asarray(rng.normal(size=e).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    full = spgemm.spmm(rows, cols, vals, x, n)
    for chunk in (128, 7, 1024):               # incl. chunk > E
        out = spgemm.spmm_chunked(rows, cols, vals, x, n, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)


def test_unknown_backend_raises():
    s, r, w, valid, rng = _random_plan_inputs(8, 16, 0)
    plan = edge_plan(jnp.asarray(s), jnp.asarray(r), 8)
    x = jnp.zeros((8, 4))
    with pytest.raises(KeyError, match="unknown sparse backend"):
        sb.aggregate(plan, None, x, backend="tpu-v7")


def test_missing_plan_section_raises():
    s, r, w, valid, rng = _random_plan_inputs(8, 16, 0)
    plan = edge_plan(jnp.asarray(s), jnp.asarray(r), 8)   # COO only
    x = jnp.zeros((8, 4), jnp.float32)
    with pytest.raises(BackendPlanError):
        sb.aggregate(plan, None, x, backend="pallas")
    with pytest.raises(BackendPlanError):
        sb.aggregate(plan, None, x, backend="distributed")


def test_plan_is_a_pytree():
    """Plans must cross jit boundaries as arguments."""
    s, r, w, valid, rng = _random_plan_inputs(16, 64, 7)
    plan = make_plan(s, r, 16, edge_weight=w,
                     backends=("dense", "chunked", "pallas"))
    x = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))

    @jax.jit
    def f(pl, xx):
        return sb.aggregate(pl, None, xx, backend="pallas")

    out = f(plan, x)
    ref = sb.aggregate(plan, None, x, backend="dense")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# distributed executor — subprocess (8 emulated devices)
# ---------------------------------------------------------------------------

DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.models.gnn import gcn
from repro.sparse import backend as sb
from repro.sparse.plan import make_plan
from repro.sparse.graph import sym_norm_weights

rng = np.random.default_rng(2)
n, e, d = 96, 600, 16
s = rng.integers(0, n, e); r = rng.integers(0, n, e)
valid = np.ones(e, bool); valid[550:] = False
w = rng.normal(size=e).astype(np.float32)
x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
plan = make_plan(s, r, n, edge_weight=w, edge_valid=valid,
                 backends=("dense", "distributed"))
assert plan.n_shards == 8
ref = sb.aggregate(plan, None, x, backend="dense")
out = sb.aggregate(plan, None, x, backend="distributed")
err = float(jnp.abs(ref - out).max())
assert err < 1e-4, f"aggregate parity {err}"

# traced vals + jit + grad through the distributed executor
@jax.jit
def loss(v, xx):
    return jnp.sum(sb.aggregate(plan, v, xx, backend="distributed") ** 2)
g = jax.grad(loss, argnums=1)(jnp.asarray(w), x)
g_ref = jax.grad(lambda v, xx: jnp.sum(
    sb.aggregate(plan, v, xx, backend="dense") ** 2), argnums=1)(
    jnp.asarray(w), x)
gerr = float(jnp.abs(g - g_ref).max())
assert gerr < 1e-3, f"grad parity {gerr}"

# accumulate-only entry
msgs = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32))
a_ref = sb.accumulate(plan, msgs, backend="dense")
a_out = sb.accumulate(plan, msgs, backend="distributed")
aerr = float(jnp.abs(a_ref - a_out).max())
assert aerr < 1e-4, f"accumulate parity {aerr}"

# full GCN forward through the registry
cfg = gcn.GCNConfig(d_in=d, d_hidden=8, n_classes=4, n_layers=2)
s2, r2, w2 = sym_norm_weights(s, r, n)
plan2 = make_plan(s2, r2, n + 1, edge_weight=w2,
                  backends=("dense", "distributed"))
params = gcn.init_params(jax.random.key(0), cfg)
xp = jnp.asarray(rng.normal(size=(n + 1, d)).astype(np.float32))
f_ref = gcn.forward(params, cfg, xp, backend="dense", plan=plan2)
f_out = gcn.forward(params, cfg, xp, backend="distributed", plan=plan2)
ferr = float(jnp.abs(f_ref - f_out).max())
assert ferr < 1e-4, f"gcn forward parity {ferr}"
print("BACKEND_DIST_OK")
"""


def test_distributed_backend_subprocess():
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "BACKEND_DIST_OK" in proc.stdout
