"""Checkpoint store: commit protocol, async, torn-write safety, elastic."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.integers(0, 10, 5), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(tmp_path, 3, t, metadata={"loss": 1.5})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, meta = store.restore(tmp_path, 3, like)
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    store.save(tmp_path, 1, t)
    step2 = tmp_path / "step_000002"
    step2.mkdir()
    (step2 / "manifest.json").write_text(json.dumps({"step": 2}))  # no COMMIT
    assert store.latest_step(tmp_path) == 1


def test_async_and_gc(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert store.committed_steps(tmp_path) == [1, 2, 3, 4]
    store.gc_keep_last(tmp_path, keep=2)
    assert store.committed_steps(tmp_path) == [3, 4]


def test_elastic_restore_new_sharding(tmp_path):
    """Restore maps onto a different device layout (topology-free manifest)."""
    t = _tree()
    store.save(tmp_path, 7, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, _ = store.restore(tmp_path, 7, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    assert out["a"].sharding == sh["a"]


def test_restore_uncommitted_step_raises_typed(tmp_path):
    """A torn (no-COMMIT) step restores as a typed CheckpointError — the
    hot-swap validate stage depends on never loading garbage."""
    t = _tree()
    step2 = tmp_path / "step_000002"
    step2.mkdir()
    (step2 / "manifest.json").write_text(json.dumps({"step": 2}))
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(store.CheckpointError, match="COMMIT"):
        store.restore(tmp_path, 2, like)


def test_restore_shape_mismatch_raises_typed(tmp_path):
    t = _tree()
    store.save(tmp_path, 1, t)
    bad_like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((3, 3), jnp.float32), t)
    with pytest.raises(store.CheckpointError, match="shape mismatch"):
        store.restore(tmp_path, 1, bad_like)
    # and validate_step alone flags incomplete manifests
    shutil.copytree(tmp_path / "step_000001", tmp_path / "step_000009")
    man = json.loads((tmp_path / "step_000009" / "manifest.json").read_text())
    man["n_leaves"] = 99
    (tmp_path / "step_000009" / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(store.CheckpointError, match="incomplete"):
        store.validate_step(tmp_path, 9)


def test_missing_leaf_file_raises_typed(tmp_path):
    t = _tree()
    store.save(tmp_path, 4, t)
    (tmp_path / "step_000004" / "leaf_00000.npy").unlink()
    with pytest.raises(store.CheckpointError, match="missing leaf"):
        store.validate_step(tmp_path, 4)


def test_gc_never_deletes_inflight_async_save(tmp_path):
    """The GC-vs-save_async race: a slow in-flight save is both shielded
    from deletion and counted toward the newest-``keep`` window."""
    import threading
    import time as _time

    for s in (1, 2, 3):
        store.save(tmp_path, s, _tree(s))

    gate = threading.Event()
    orig_save = store.save

    def slow_save(ckpt_dir, step, tree, metadata=None):
        gate.wait(10.0)               # hold the save un-committed
        return orig_save(ckpt_dir, step, tree, metadata)

    ck = store.AsyncCheckpointer(tmp_path)
    store.save, saved = slow_save, store.save
    try:
        ck.save_async(9, _tree(9))
        # the in-flight step is registered the moment save_async returns
        assert store.inflight_steps(tmp_path) == [9]
        # GC with keep=2: window = {3, 9} — step 9 counts toward it even
        # though uncommitted, so steps 1 AND 2 go, step 3 stays
        store.gc_keep_last(tmp_path, keep=2)
        assert store.committed_steps(tmp_path) == [3]
        assert (tmp_path / "step_000003").exists()
    finally:
        gate.set()
        ck.wait()
        store.save = saved
    assert store.committed_steps(tmp_path) == [3, 9]
    assert store.inflight_steps(tmp_path) == []
    # GC after commit behaves classically
    store.gc_keep_last(tmp_path, keep=1)
    assert store.committed_steps(tmp_path) == [9]
