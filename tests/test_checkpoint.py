"""Checkpoint store: commit protocol, async, torn-write safety, elastic."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.integers(0, 10, 5), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(tmp_path, 3, t, metadata={"loss": 1.5})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, meta = store.restore(tmp_path, 3, like)
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    store.save(tmp_path, 1, t)
    step2 = tmp_path / "step_000002"
    step2.mkdir()
    (step2 / "manifest.json").write_text(json.dumps({"step": 2}))  # no COMMIT
    assert store.latest_step(tmp_path) == 1


def test_async_and_gc(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert store.committed_steps(tmp_path) == [1, 2, 3, 4]
    store.gc_keep_last(tmp_path, keep=2)
    assert store.committed_steps(tmp_path) == [3, 4]


def test_elastic_restore_new_sharding(tmp_path):
    """Restore maps onto a different device layout (topology-free manifest)."""
    t = _tree()
    store.save(tmp_path, 7, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, _ = store.restore(tmp_path, 7, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    assert out["a"].sharding == sh["a"]
