"""Device-side forest sampler (``serve/device_sampler.py``): draw-for-draw
equality with the host sampler, and the fused device-sampling serving mode."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.serve.device_sampler import (DeviceSamplerPlane,
                                        sample_forest_device, tree_key_mix)
from repro.sparse import sampler
from repro.sparse.graph import coo_to_csr


def _graph(n=120, e=900, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    indptr, indices, _ = coo_to_csr(s, r, n)
    return indptr, indices, n


def _assert_forest_equal(host, dev):
    assert len(host) == len(dev)
    for h, d in zip(host, dev):
        assert np.array_equal(np.asarray(h.node_ids), np.asarray(d.node_ids))
        for hv, dv in zip(h.hop_valid, d.hop_valid):
            assert np.array_equal(np.asarray(hv), np.asarray(dv))


# ---------------------------------------------------------------------------
# exact host/device equality — the hard invariant behind the serving parity
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.lists(st.integers(1, 5), min_size=1,
                                    max_size=3), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_device_matches_host_exactly(b, fanouts, key):
    indptr, indices, n = _graph()
    seeds = np.random.default_rng(key).integers(0, n, b)
    host = sampler.sample_forest(indptr, indices, seeds, fanouts, key=key)
    dev = sample_forest_device(indptr, indices, seeds, fanouts, key=key)
    _assert_forest_equal(host, dev)


def test_device_matches_host_custom_tree_keys():
    indptr, indices, n = _graph(seed=3)
    seeds = np.array([5, 5, 7, 90])       # repeated seed, distinct tree keys
    tks = np.array([3, 11, 2**40 + 1, 0], np.uint64)
    host = sampler.sample_forest(indptr, indices, seeds, (4, 2), key=9,
                                 tree_keys=tks)
    dev = sample_forest_device(indptr, indices, seeds, (4, 2), key=9,
                               tree_keys=tks)
    _assert_forest_equal(host, dev)
    # same seed, different tree_key → different draws (counter really mixes)
    assert not np.array_equal(np.asarray(host[0].node_ids),
                              np.asarray(host[1].node_ids))


def test_device_matches_host_edgeless_graph():
    indptr = np.zeros(33, np.int64)       # 32 nodes, zero edges
    indices = np.zeros(0, np.int64)
    seeds = np.array([0, 7, 31])
    host = sampler.sample_forest(indptr, indices, seeds, (3, 2), key=1)
    dev = sample_forest_device(indptr, indices, seeds, (3, 2), key=1)
    _assert_forest_equal(host, dev)
    for t in dev:
        assert not np.asarray(t.hop_valid[0]).any()


def test_device_matches_host_fanout_exceeds_degree():
    # a 4-node chain: degrees ≤ 1, fanout 5 → draws repeat the one neighbor
    indptr, indices, _ = coo_to_csr(np.array([0, 1, 2]),
                                    np.array([1, 2, 3]), 4)
    host = sampler.sample_forest(indptr, indices, np.array([1, 3]), (5,),
                                 key=4)
    dev = sample_forest_device(indptr, indices, np.array([1, 3]), (5,),
                               key=4)
    _assert_forest_equal(host, dev)


def test_kernel_and_jnp_draw_paths_agree():
    indptr, indices, n = _graph(seed=6)
    seeds = np.random.default_rng(6).integers(0, n, 6)
    ref = sample_forest_device(indptr, indices, seeds, (4, 3), key=2,
                               use_kernel=False)
    ker = sample_forest_device(indptr, indices, seeds, (4, 3), key=2,
                               use_kernel=True)
    _assert_forest_equal(ref, ker)


def test_grouping_invariance_on_device():
    # sampling trees together or alone yields identical tables
    indptr, indices, n = _graph(seed=8)
    seeds = np.array([3, 60, 99])
    tks = np.array([7, 8, 9], np.uint64)
    joint = sample_forest_device(indptr, indices, seeds, (3, 3), key=5,
                                 tree_keys=tks)
    for i in range(3):
        alone = sample_forest_device(indptr, indices, seeds[i:i + 1], (3, 3),
                                     key=5, tree_keys=tks[i:i + 1])
        _assert_forest_equal([joint[i]], alone)


def test_sample_bucket_layout_matches_stack_trees():
    import jax.numpy as jnp

    from repro.serve.buckets import stack_trees

    indptr, indices, n = _graph(seed=9)
    seeds = np.array([2, 40, 77, 101])
    tks = np.arange(4, dtype=np.uint64)
    plane = DeviceSamplerPlane(indptr, indices, (3, 2), key=6)
    tk_hi, tk_lo = tree_key_mix(tks)
    node_ids, hop_valid = plane.sample_bucket(
        jnp.asarray(seeds.astype(np.int32)), jnp.asarray(tk_hi),
        jnp.asarray(tk_lo), jnp.ones((4,), bool))
    trees = sampler.sample_forest(indptr, indices, seeds, (3, 2), key=6,
                                  tree_keys=tks)
    host_nodes, host_valid = stack_trees(trees, 4, (3, 2))
    assert np.array_equal(np.asarray(node_ids), np.asarray(host_nodes))
    assert np.array_equal(np.asarray(hop_valid), np.asarray(host_valid))


def test_padding_lanes_are_dead():
    import jax.numpy as jnp

    indptr, indices, n = _graph(seed=10)
    plane = DeviceSamplerPlane(indptr, indices, (3, 2), key=0)
    tk_hi, tk_lo = tree_key_mix(np.arange(3, dtype=np.uint64))
    live = jnp.asarray(np.array([True, False, True]))
    levels, valid = plane.sample_levels(
        jnp.asarray(np.array([5, 0, 9], np.int32)), jnp.asarray(tk_hi),
        jnp.asarray(tk_lo), live)
    for lv in levels:
        assert np.all(np.asarray(lv)[1] == -1)     # dead lane: ghost nodes
    for v in valid:
        assert not np.asarray(v)[1].any()          # dead lane: no edges


# ---------------------------------------------------------------------------
# serving engine in device-sampling mode
# ---------------------------------------------------------------------------

def _server(sampler_mode, seed=0):
    from repro.launch.gnn_serve import build_world
    from repro.serve import GNNServer

    cfg, params, indptr, indices, store = build_world("gcn", 256, 1024, 16,
                                                      seed=seed)
    return GNNServer("gcn", cfg, params, indptr, indices, store,
                     fanouts=(3, 2), backend="dense", sampler=sampler_mode,
                     max_batch_seeds=4, max_wait_ms=1.0, seed=seed)


def test_engine_rejects_unknown_sampler():
    with pytest.raises(ValueError):
        _server("gpu")


def test_engine_device_mode_matches_host_mode():
    seeds = [3, 77, 200, 9, 141, 55]
    outs = {}
    for mode in ("host", "device"):
        with _server(mode) as srv:
            srv.warmup()
            reqs = [srv.submit([s]) for s in seeds]
            srv.drain(timeout=600)
            outs[mode] = np.stack([r.result for r in reqs])
            assert srv.steps.builds >= 1
    # same rids → same tree keys → identical trees; forward is the same
    # program modulo sampling placement, so results agree to float tolerance
    assert np.allclose(outs["host"], outs["device"], atol=1e-5)


def test_engine_device_mode_offline_parity():
    from repro.serve.engine import offline_replay

    with _server("device", seed=1) as srv:
        srv.warmup()
        reqs = [srv.submit([s]) for s in (10, 20, 30, 40)]
        srv.drain(timeout=600)
        for r in reqs:
            ref = offline_replay(srv, r)   # host-sampled replay
            assert np.abs(np.asarray(r.result) - ref).max() <= 1e-5
