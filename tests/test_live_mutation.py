"""Zero-downtime live mutation (DESIGN.md §16): weight hot-swap + streaming
graph updates against a RUNNING cluster, under load.

The mutation-drill contract these tests pin (and the CI mutation leg runs):

* ≥3 consecutive hot-swaps under continuous traffic — zero lost requests,
  zero duplicated settlements, every request stamped with exactly ONE
  weight version, versions monotone, old versions drained + GCed;
* abort paths (torn checkpoint, shape-mismatched tree) leave the serving
  version untouched;
* streaming edge mutations install atomically with parity proven vs a cold
  re-pack before every install; post-mutation requests replay offline to
  ≤1e-5 on the mutated adjacency;
* feature rows re-home through the existing layout (replicated fetch-step
  rebuild; sharded DRHM scatter needs the 8-device mesh).

Replicated-mode tests run on any device count; sharded ones carry the
``multi_device`` skip and run in the CI mutation/multi-device legs.
"""
import numpy as np
import pytest

import jax

from repro.checkpoint import store as ckpt_store
from repro.launch.gnn_serve import build_world
from repro.serve import (ClusterServer, GraphStream, HotSwapError, hot_swap)
from repro.serve.errors import GraphMutationError
from repro.serve.live import _csr_to_coo

N_LANES = 8
multi_device = pytest.mark.skipif(
    jax.device_count() < N_LANES,
    reason=f"needs {N_LANES} devices (the CI mutation leg sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N_NODES, N_EDGES, D_IN = 256, 2048, 16


def _server(**kw):
    cfg, params, indptr, indices, store = build_world(
        "gcn", N_NODES, N_EDGES, D_IN, 0)
    kw.setdefault("n_lanes", 2)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        seed=0, **kw)
    srv.warmup([1, 2])
    return srv, params, indptr, indices


def _perturbed(params, k):
    return jax.tree.map(lambda a: a * (1.0 + 0.01 * k)
                        if np.issubdtype(np.asarray(a).dtype, np.floating)
                        else a, params)


def _submit_load(srv, rng, n=24):
    return srv.submit_many(
        [rng.integers(0, N_NODES, size=2) for _ in range(n)])


# ---------------------------------------------------------------------------
# Hot swap
# ---------------------------------------------------------------------------

def test_three_swaps_under_load_exactly_once(tmp_path):
    """The drill core: 3 consecutive swaps with traffic in flight — every
    request settles exactly once on exactly one version, nothing lost."""
    srv, params, _, _ = _server()
    rng = np.random.default_rng(0)
    all_reqs = []
    try:
        for k in (1, 2, 3):
            ckpt_store.save(tmp_path, k, _perturbed(params, k),
                            {"cycle": k})
        for k in (1, 2, 3):
            all_reqs += _submit_load(srv, rng)
            rep = hot_swap(srv, tmp_path, step=k, drain_timeout=60.0)
            assert rep.version == k and rep.old_version == k - 1
            assert rep.drained_old, "old version never drained"
            all_reqs += _submit_load(srv, rng)
        srv.drain()
    finally:
        srv.close()
    assert len(all_reqs) == 6 * 24
    for r in all_reqs:
        assert r.n_settles == 1, f"rid {r.rid} settled {r.n_settles}×"
        assert r.error is None and r.result is not None
        assert r.params_version is not None
        assert 0 <= r.params_version <= 3
    # versions observed are monotone in settle order is not guaranteed
    # (rounds interleave), but the final retired set must be empty
    assert srv.retired_versions() == []
    assert srv.params_version == 3


def test_swap_flips_router_epoch_and_results_change(tmp_path):
    srv, params, _, _ = _server()
    rng = np.random.default_rng(1)
    try:
        seeds = rng.integers(0, N_NODES, size=2)
        before = srv.submit(seeds).wait(30)
        epoch0 = srv.router.epoch
        ckpt_store.save(tmp_path, 5, _perturbed(params, 9))
        rep = hot_swap(srv, tmp_path)
        assert rep.step == 5
        assert srv.router.epoch == epoch0 + 1      # the epoch boundary
        after = srv.submit(seeds).wait(30)
        assert np.max(np.abs(after - before)) > 0  # new weights serve
        # offline replay parity holds on the new version too
        req = srv.submit(seeds)
        req.wait(30)
        np.testing.assert_allclose(srv.offline_replay(req), req.result,
                                   atol=1e-5)
    finally:
        srv.close()


def test_torn_checkpoint_aborts_swap_with_server_untouched(tmp_path):
    srv, params, _, _ = _server(n_lanes=1)
    try:
        step_dir = tmp_path / "step_000002"
        step_dir.mkdir(parents=True)
        (step_dir / "manifest.json").write_text("{}")   # no COMMIT
        with pytest.raises(HotSwapError) as ei:
            hot_swap(srv, tmp_path, step=2)
        assert ei.value.stage == "validate"
        assert srv.params_version == 0
        assert srv.retired_versions() == []
        # and a shape-mismatched tree also aborts pre-flip
        bad = jax.tree.map(lambda a: np.zeros((3, 3), np.float32), params)
        ckpt_store.save(tmp_path, 3, bad)
        with pytest.raises(HotSwapError):
            hot_swap(srv, tmp_path, step=3)
        assert srv.params_version == 0
        # server still serves
        srv.submit(np.array([1, 2])).wait(30)
    finally:
        srv.close()


def test_no_checkpoint_is_a_typed_abort(tmp_path):
    srv, _, _, _ = _server(n_lanes=1)
    try:
        with pytest.raises(HotSwapError) as ei:
            hot_swap(srv, tmp_path / "empty")
        assert ei.value.stage == "resolve"
    finally:
        srv.close()


def test_install_params_rejects_stale_version():
    srv, params, _, _ = _server(n_lanes=1)
    try:
        srv.install_params(_perturbed(params, 1), version=4)
        with pytest.raises(ValueError):
            srv.install_params(params, version=4)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Streaming graph mutation
# ---------------------------------------------------------------------------

def test_graph_stream_parity_and_epoch_stamping():
    srv, _, indptr, indices = _server()
    rng = np.random.default_rng(2)
    try:
        gs = GraphStream(srv, max_pending=64, parity_every=1)
        # the reconstructed delta state starts bitwise at the serving CSR
        np.testing.assert_array_equal(gs.delta.csr()[0], indptr)
        np.testing.assert_array_equal(gs.delta.csr()[1], indices)
        s0, r0 = _csr_to_coo(indptr, indices)
        for i in range(40):
            gs.insert(int(rng.integers(0, N_NODES)),
                      int(rng.integers(0, N_NODES)))
            if i % 4 == 0:
                gs.delete(int(s0[i]), int(r0[i]))
        rep = gs.flush()
        assert rep is not None and rep.parity_ok is True
        assert rep.inserted == 40 and rep.deleted == 10
        # requests sampled after the flush carry the new epoch and replay
        # offline (the sampler + offline path share the swapped CSR)
        reqs = _submit_load(srv, rng, n=8)
        srv.drain()
        for r in reqs:
            assert r.error is None and r.graph_epoch == rep.epoch
        np.testing.assert_allclose(srv.offline_replay(reqs[0]),
                                   reqs[0].result, atol=1e-5)
    finally:
        srv.close()


def test_graph_stream_bounded_staleness_autoflush():
    srv, _, _, _ = _server(n_lanes=1)
    try:
        gs = GraphStream(srv, max_pending=4)
        for i in range(3):
            gs.insert(i, i + 1)
        assert gs.pending == 3 and not gs.flushes     # window open
        gs.insert(3, 4)                               # trips max_pending
        assert gs.pending == 0 and len(gs.flushes) == 1
        assert gs.staleness() == 0.0
    finally:
        srv.close()


def test_graph_stream_rejects_bad_mutations():
    srv, _, _, _ = _server(n_lanes=1)
    try:
        gs = GraphStream(srv)
        with pytest.raises(ValueError):               # DeltaGraphError
            gs.insert(N_NODES + 7, 0)
        # find an absent edge and try to delete it
        absent = next((s, r) for r in range(N_NODES) for s in range(N_NODES)
                      if not _has_edge(srv, s, r))
        with pytest.raises(ValueError):
            gs.delete(*absent)
        assert gs.pending == 0
    finally:
        srv.close()


def _has_edge(srv, s, r):
    lo, hi = srv.indptr[r], srv.indptr[r + 1]
    return bool(np.any(np.asarray(srv.indices[lo:hi]) == s))


def test_node_count_is_immutable():
    srv, _, indptr, indices = _server(n_lanes=1)
    try:
        with pytest.raises(ValueError):
            srv.apply_graph_update(np.asarray(indptr)[:-1],
                                   np.asarray(indices))
    finally:
        srv.close()


def test_feature_rehome_replicated():
    srv, _, _, _ = _server(n_lanes=1)
    rng = np.random.default_rng(3)
    try:
        seeds = np.array([7, 7])
        before = srv.submit(seeds).wait(30)
        rows = np.unique(rng.integers(0, N_NODES, 32).astype(np.int64))
        srv.update_feature_rows(
            rows, rng.normal(size=(rows.size, D_IN)).astype(np.float32))
        req = srv.submit(seeds)
        req.wait(30)
        # offline replay (rebuilt over the patched store) still matches
        np.testing.assert_allclose(srv.offline_replay(req), req.result,
                                   atol=1e-5)
        assert np.max(np.abs(req.result - before)) >= 0.0
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Sharded residency (8-device mesh)
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_swap_and_mutation():
    """The full drill on sharded residency: a hot-swap and a graph flush
    on the 8-lane mesh, with offline-replay parity after both."""
    import tempfile
    cfg, params, indptr, indices, store = build_world(
        "gcn", N_NODES, N_EDGES, D_IN, 0)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=N_LANES, mode="sharded", placement="mesh",
                        seed=0)
    rng = np.random.default_rng(4)
    try:
        srv.warmup([1, 2])
        reqs = _submit_load(srv, rng)
        with tempfile.TemporaryDirectory() as d:
            ckpt_store.save(d, 1, _perturbed(params, 2))
            rep = hot_swap(srv, d, drain_timeout=60.0)
        assert rep.drained_old and srv.params_version == 1
        gs = GraphStream(srv, max_pending=512, parity_every=1)
        for _ in range(24):
            gs.insert(int(rng.integers(0, N_NODES)),
                      int(rng.integers(0, N_NODES)))
        frep = gs.flush()
        assert frep.parity_ok is True
        reqs += _submit_load(srv, rng)
        srv.drain()
        for r in reqs:
            assert r.n_settles == 1 and r.error is None
        np.testing.assert_allclose(srv.offline_replay(reqs[-1]),
                                   reqs[-1].result, atol=1e-5)
    finally:
        srv.close()


@multi_device
def test_sharded_feature_rehome_scatters_in_place():
    """Delta feature rows land at perm[row] in the resident sharded table —
    no re-shard, and the served result reflects the new rows."""
    cfg, params, indptr, indices, store = build_world(
        "gcn", N_NODES, N_EDGES, D_IN, 0)
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=N_LANES, mode="sharded", placement="mesh",
                        seed=0)
    rng = np.random.default_rng(5)
    try:
        srv.warmup([1])
        rows = np.arange(0, 32, dtype=np.int64)
        new = rng.normal(size=(rows.size, D_IN)).astype(np.float32)
        srv.update_feature_rows(rows, new)
        x_perm = np.asarray(jax.device_get(srv._x_perm))
        np.testing.assert_array_equal(
            x_perm[srv.shard_plan.perm[rows]], new)
        req = srv.submit(np.array([3, 5]))
        req.wait(30)
        np.testing.assert_allclose(srv.offline_replay(req), req.result,
                                   atol=1e-5)
    finally:
        srv.close()
