"""Continuous-batching engine: equality with offline one-at-a-time decoding,
slot reuse under mixed generation lengths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import synthetic as syn
from repro.models.lm import transformer as T
from repro.train.serving import ContinuousBatcher, Request


def _engine(cfg, s_max, n_slots):
    params = T.init_params(jax.random.key(0), cfg)

    prefill = jax.jit(lambda t: T.prefill(params, cfg, t))
    decode = jax.jit(
        lambda tok, cache, pos: T.decode_step_ragged(params, cfg, tok, cache,
                                                     pos))

    def init_cache(b, s):
        return T.init_cache(cfg, b, s)

    return params, prefill, decode, init_cache


def _offline(params, cfg, prompt, max_new, s_max):
    logits, kv = T.prefill(params, cfg, jnp.asarray(prompt[None, :]))
    cache = T.init_cache(cfg, 1, s_max)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim), cache, kv)
    toks = [int(jnp.argmax(logits[0]))]
    pos = prompt.shape[0]
    for _ in range(max_new - 1):
        logits, cache = T.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


def test_continuous_batching_matches_offline():
    cfg = registry.get_config("qwen3-0.6b", reduced=True)
    s_max, n_slots = 48, 3
    params, prefill, decode, init_cache = _engine(cfg, s_max, n_slots)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8 + 3 * i,
                                        dtype=np.int64).astype(np.int32),
                    max_new=5 + 2 * i)
            for i in range(5)]          # 5 requests > 3 slots ⇒ queueing

    eng = ContinuousBatcher(n_slots, s_max, init_cache, prefill, decode)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)

    for r in reqs:
        ref = _offline(params, cfg, r.prompt, r.max_new, s_max)
        assert r.out == ref, (r.rid, r.out, ref)
