"""MoE dispatch invariants (capacity, combine weighting, DRHM-ish balance)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.models.lm import transformer as T


def _cfg(**kw):
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                d_ff=64, vocab=128, n_experts=4, top_k=2, moe_layer_period=1,
                q_chunk=8, kv_chunk=8)
    base.update(kw)
    return T.LMConfig(**base)


@given(st.integers(0, 10_000), st.integers(1, 2), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_moe_output_finite_and_shaped(seed, top_k, n_experts):
    cfg = _cfg(top_k=top_k, n_experts=n_experts)
    rng = np.random.default_rng(seed)
    p = T._moe_mlp_init(jax.random.key(seed), cfg, 1)
    p = jax.tree.map(lambda x: x[0], p)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    cap = T.moe_capacity(cfg, 32)
    y = T.moe_mlp(p, cfg, x, cap)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_huge_capacity_equals_dense_expert_mix():
    """With capacity ≥ T·k nothing drops: output = Σ p_e · FFN_e(x)."""
    cfg = _cfg(top_k=4, n_experts=4)          # top_k = E ⇒ all experts
    rng = np.random.default_rng(0)
    p = T._moe_mlp_init(jax.random.key(0), cfg, 1)
    p = jax.tree.map(lambda x: x[0], p)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)).astype(np.float32))
    y = T.moe_mlp(p, cfg, x, capacity=1024)
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    ref = jnp.zeros_like(xt)
    for e in range(4):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wu"][e])
        ref = ref + probs[:, e:e + 1] * (h @ p["wd"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_capacity_drop_bounds_buffer():
    """No expert receives more than `capacity` tokens (overflow dropped)."""
    cfg = _cfg(top_k=1, n_experts=2)
    rng = np.random.default_rng(1)
    p = T._moe_mlp_init(jax.random.key(1), cfg, 1)
    p = jax.tree.map(lambda x: x[0], p)
    # capacity 8 with 64 tokens: must not error and must stay finite
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
    y = T.moe_mlp(p, cfg, x, capacity=8)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_rounding():
    cfg = _cfg(top_k=2, n_experts=4, capacity_factor=1.25)
    c = T.moe_capacity(cfg, 1024)
    assert c % 128 == 0
    assert c >= 1024 * 2 / 4 * 1.25
