"""DRHM (paper C2) property tests — consistency, bijectivity, uniformity."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.core import drhm


@given(st.integers(2, 12), st.integers(0, 2**30))
@settings(max_examples=50, deadline=None)
def test_permutation_bijective(log_n, gamma):
    n = 1 << log_n
    perm = drhm.drhm_permutation(n, gamma | 1)
    assert np.array_equal(np.sort(perm), np.arange(n))


@given(st.integers(0, 2**30), st.integers(2, 512))
@settings(max_examples=30, deadline=None)
def test_hash_consistency_and_range(gamma, n_bins):
    tags = jnp.arange(1000, dtype=jnp.int32)
    g = jnp.uint32(gamma * 2 + 1)
    h1 = drhm.drhm_hash(tags, g, n_bins)
    h2 = drhm.drhm_hash(tags, g, n_bins)
    assert bool(jnp.all(h1 == h2))          # consistency (paper §2.4)
    assert bool(jnp.all((h1 >= 0) & (h1 < n_bins)))


def test_shard_plan_exact_balance():
    """Bijective permutation ⇒ every shard owns exactly n_pad/n_shards slots."""
    plan = drhm.plan_row_sharding(10_000, 16, gamma=0x9E3779B1)
    owners = plan.owner_of(np.arange(10_000))
    counts = np.bincount(owners, minlength=16)
    assert counts.max() - counts.min() <= np.ceil(10_000 / plan.n_pad * 16) + 1
    # all-pad balance is exact
    all_owners = plan.perm // plan.rows_per_shard
    assert np.bincount(all_owners).std() == 0


def test_drhm_beats_ring_on_strided_pattern():
    """The paper's hot-spot scenario: strided tags pile onto one ring bin."""
    n_bins = 32
    tags = jnp.asarray((np.arange(20_000) * n_bins) % (1 << 16))
    ring_imb = float(drhm.imbalance(drhm.ring_map(tags, n_bins), n_bins))
    g = drhm.reseed(__import__("jax").random.key(0))
    drhm_imb = float(drhm.imbalance(drhm.drhm_map(tags, n_bins, gamma=g),
                                    n_bins))
    assert ring_imb > 5.0 * drhm_imb        # ring collapses, DRHM stays flat


def test_reseed_changes_mapping():
    import jax
    tags = jnp.arange(4096)
    h1 = drhm.drhm_hash(tags, drhm.reseed(jax.random.key(1)), 64)
    h2 = drhm.drhm_hash(tags, drhm.reseed(jax.random.key(2)), 64)
    assert not bool(jnp.all(h1 == h2))


def test_inverse_permutation():
    perm = drhm.drhm_permutation(256, 77)
    inv = drhm.invert_permutation(perm)
    assert np.array_equal(perm[inv], np.arange(256))
