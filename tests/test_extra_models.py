"""Smoke + training tests for the beyond-assignment GNNs (GraphSAGE, GIN),
including GraphSAGE on its native fanout-sampled minibatch path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import gin, sage
from repro.sparse import sampler
from repro.sparse.graph import coo_to_csr


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree))


def test_sage_on_sampled_minibatch():
    rng = np.random.default_rng(0)
    n, e, d = 500, 4000, 16
    s = rng.integers(0, n, e)
    r = rng.integers(0, n, e)
    feats = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.integers(0, 5, n).astype(np.int32)
    indptr, indices, _ = coo_to_csr(s, r, n)

    seeds = rng.integers(0, n, 16)
    sub = sampler.sample_subgraph(indptr, indices, seeds, (5, 3), rng)
    ids = np.where(sub.node_ids >= 0, sub.node_ids, 0)
    x = feats[ids]
    senders = np.concatenate(sub.hop_senders)
    receivers = np.concatenate(sub.hop_receivers)
    valid = np.concatenate(sub.hop_valid)
    labels = np.zeros(len(ids), np.int32)
    labels[:16] = y[seeds]
    mask = np.zeros(len(ids), bool)
    mask[:16] = True

    cfg = sage.SAGEConfig(d_in=d, d_hidden=8, n_classes=5)
    params = sage.init_params(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(sage.loss_fn)(
        params, cfg, jnp.asarray(x), jnp.asarray(senders),
        jnp.asarray(receivers), jnp.asarray(valid), jnp.asarray(labels),
        jnp.asarray(mask))
    assert np.isfinite(float(loss))
    assert _finite(grads)


def test_gin_graph_classification_learns():
    rng = np.random.default_rng(1)
    batch, n, e = 16, 10, 30
    # two classes distinguished by feature mean — learnable signal
    labels = rng.integers(0, 2, batch).astype(np.int32)
    xs, ss, rs, gid = [], [], [], []
    for b in range(batch):
        xs.append(rng.normal(size=(n, 8)).astype(np.float32)
                  + labels[b] * 0.75)
        ss.append(rng.integers(0, n, e) + b * n)
        rs.append(rng.integers(0, n, e) + b * n)
        gid.append(np.full(n, b))
    x = jnp.asarray(np.concatenate(xs))
    senders = jnp.asarray(np.concatenate(ss))
    receivers = jnp.asarray(np.concatenate(rs))
    valid = jnp.ones(batch * e, bool)
    graph_ids = jnp.asarray(np.concatenate(gid))

    cfg = gin.GINConfig(d_in=8, d_hidden=16, n_classes=2, n_layers=2)
    params = gin.init_params(jax.random.key(0), cfg)
    from repro.optim import adamw
    opt = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=5e-3)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(gin.loss_fn)(
            p, cfg, x, senders, receivers, valid, graph_ids, batch,
            jnp.asarray(labels))
        p, o, _ = adamw.apply_updates(p, g, o, ocfg)
        return p, o, loss

    losses = []
    for _ in range(40):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0]
