"""Per-architecture reduced-config smoke tests: one forward/train step on
CPU, asserting output shapes and finiteness (the FULL configs are exercised
only via the dry-run, per the assignment)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import synthetic as syn
from repro.sparse import triplets as tri
from repro.sparse.graph import make_graph, sym_norm_weights

LM_ARCHS = ["llama4-maverick-400b-a17b", "grok-1-314b", "gemma-7b",
            "qwen3-0.6b", "deepseek-67b"]


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_train_step(arch):
    from repro.models.lm import transformer as T
    cfg = registry.get_config(arch, reduced=True)
    params = T.init_params(jax.random.key(0), cfg)
    toks = jnp.asarray(syn.token_batch(2, 32, cfg.vocab))
    loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, toks)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(grads)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_decode(arch):
    from repro.models.lm import transformer as T
    cfg = registry.get_config(arch, reduced=True)
    params = T.init_params(jax.random.key(0), cfg)
    cache = T.init_cache(cfg, 2, 16)
    logits, cache = T.decode_step(
        params, cfg, jnp.zeros((2, 1), jnp.int32), cache, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)


def test_lm_prefill_decode_consistency():
    """decode(t+1) after prefill(≤t) must match teacher-forced forward."""
    from repro.models.lm import transformer as T
    cfg = registry.get_config("qwen3-0.6b", reduced=True)
    cfg = dataclasses.replace(cfg, q_chunk=8, kv_chunk=8)
    params = T.init_params(jax.random.key(1), cfg)
    toks = jnp.asarray(syn.token_batch(2, 16, cfg.vocab, seed=3))
    logits_p, kv = T.prefill(params, cfg, toks[:, :8])
    cache = T.init_cache(cfg, 2, 16)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim), cache, kv)
    logits_d, _ = T.decode_step(params, cfg, toks[:, 8:9], cache, jnp.int32(8))
    # reference: full forward over 9 tokens, logits at position 8
    h = T.forward(params, cfg, toks[:, :9])
    ref = h[:, 8] @ T.unembed_matrix(params, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def _flat_molecules(batch=3, n=10, e=24, seed=0):
    species, pos, sd, rc, val, tgt = syn.molecule_batch(batch, n, e, seed=seed)
    offs = (np.arange(batch) * n)[:, None]
    return (species.reshape(-1), pos.reshape(-1, 3),
            (sd + offs).reshape(-1), (rc + offs).reshape(-1),
            val.reshape(-1), np.repeat(np.arange(batch), n), tgt)


def test_gcn_reduced_step():
    from repro.models.gnn import gcn
    cfg = registry.get_config("gcn-cora", reduced=True)
    rng = np.random.default_rng(0)
    n, e = 50, 200
    s, r = rng.integers(0, n, e), rng.integers(0, n, e)
    s2, r2, w = sym_norm_weights(s, r, n)
    g = make_graph(s2, r2, n, w)
    x = jnp.asarray(rng.normal(size=(n + 1, cfg.d_in)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n + 1), jnp.int32)
    mask = jnp.asarray(np.arange(n + 1) < 30)
    params = gcn.init_params(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(gcn.loss_fn)(
        params, cfg, x, g.senders, g.receivers, g.edge_weight, g.edge_valid,
        labels, mask)
    assert np.isfinite(float(loss))
    assert _finite(grads)
    logits = gcn.forward(params, cfg, x, g.senders, g.receivers,
                         g.edge_weight, g.edge_valid)
    assert logits.shape == (x.shape[0], cfg.n_classes)


def test_gat_reduced_step():
    from repro.models.gnn import gat
    cfg = registry.get_config("gat-cora", reduced=True)
    rng = np.random.default_rng(1)
    n, e = 40, 150
    g = make_graph(rng.integers(0, n, e), rng.integers(0, n, e), n)
    x = jnp.asarray(rng.normal(size=(n + 1, cfg.d_in)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n + 1), jnp.int32)
    mask = jnp.asarray(np.arange(n + 1) < 20)
    params = gat.init_params(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(gat.loss_fn)(
        params, cfg, x, g.senders, g.receivers, g.edge_valid, labels, mask)
    assert np.isfinite(float(loss))
    assert _finite(grads)


def test_schnet_reduced_step():
    from repro.models.gnn import schnet
    cfg = registry.get_config("schnet", reduced=True)
    sp, pos, sd, rc, val, gid, tgt = _flat_molecules()
    params = schnet.init_params(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(schnet.loss_fn)(
        params, cfg, jnp.asarray(sp), jnp.asarray(pos), jnp.asarray(sd),
        jnp.asarray(rc), jnp.asarray(val), jnp.asarray(gid), 3,
        jnp.asarray(tgt))
    assert np.isfinite(float(loss))
    assert _finite(grads)
    e = schnet.forward(params, cfg, jnp.asarray(sp), jnp.asarray(pos),
                       jnp.asarray(sd), jnp.asarray(rc), jnp.asarray(val),
                       jnp.asarray(gid), 3)
    assert e.shape == (3,)


def test_dimenet_reduced_step():
    from repro.models.gnn import dimenet
    cfg = registry.get_config("dimenet", reduced=True)
    sp, pos, sd, rc, val, gid, tgt = _flat_molecules(seed=2)
    t_in, t_out, t_val = tri.build_triplets(sd, rc, cfg.max_triplets_per_edge)
    params = dimenet.init_params(jax.random.key(0), cfg)
    loss, grads = jax.value_and_grad(dimenet.loss_fn)(
        params, cfg, jnp.asarray(sp), jnp.asarray(pos), jnp.asarray(sd),
        jnp.asarray(rc), jnp.asarray(val), jnp.asarray(t_in),
        jnp.asarray(t_out), jnp.asarray(t_val), jnp.asarray(gid), 3,
        jnp.asarray(tgt))
    assert np.isfinite(float(loss))
    assert _finite(grads)


def test_dlrm_reduced_step():
    from repro.models.recsys import dlrm
    cfg = registry.get_config("dlrm-rm2", reduced=True)
    params = dlrm.init_params(jax.random.key(0), cfg)
    dense, ids, labels = syn.dlrm_batch(16, cfg.n_dense, cfg.vocab_sizes)
    loss, grads = jax.value_and_grad(dlrm.loss_fn)(
        params, cfg, jnp.asarray(dense), jnp.asarray(ids), jnp.asarray(labels))
    assert np.isfinite(float(loss))
    assert _finite(grads)
    scores = dlrm.retrieval_step(params, cfg, jnp.asarray(dense[:1]),
                                 jnp.asarray(ids[:1]),
                                 jnp.ones((512, cfg.embed_dim)))
    assert scores.shape == (1, 512)


def test_all_cells_have_input_specs():
    """Every (arch × shape) cell yields ShapeDtypeStructs, no allocation."""
    n = 0
    for arch_id, shape_name in registry.all_cells():
        specs, statics = registry.input_specs(arch_id, shape_name)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        n += 1
    assert n == 40
