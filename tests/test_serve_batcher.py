"""Request plane: scheduler utilities + dynamic batcher property tests.

The batcher runs on a virtual clock here — the properties (every request
batched exactly once, deadlines respected, capacity never exceeded, no
head-of-line blocking) are asserted over seeded random arrival traces
without any real threads or sleeps.
"""
import numpy as np
import pytest

from repro.serve.batcher import DynamicBatcher, ServeRequest
from repro.serve.scheduler import SlotPool, pack_fifo


# ---------------------------------------------------------------------------
# scheduler primitives
# ---------------------------------------------------------------------------

def test_slot_pool_acquire_release():
    pool = SlotPool(3)
    assert pool.free_count == 3
    assert pool.acquire("a") == 0
    assert pool.acquire("b") == 1
    assert pool.acquire("c") == 2
    assert pool.acquire("d") is None          # full
    assert pool.release(1) == "b"
    assert pool.free_count == 1
    assert pool.acquire("d") == 1             # lowest free slot reused
    assert pool.live() == [(0, "a"), (1, "d"), (2, "c")]


def test_slot_pool_double_release_raises():
    pool = SlotPool(2)
    pool.acquire("x")
    pool.release(0)
    with pytest.raises(ValueError):
        pool.release(0)


def test_pack_fifo_skip_ahead():
    sizes = {"a": 10, "b": 9, "c": 3, "d": 2}
    taken, rest, used = pack_fifo(list("abcd"), 16, size_of=sizes.get)
    assert taken == ["a", "c", "d"] and rest == ["b"] and used == 15
    # strict FIFO stops at the first misfit
    taken, rest, _ = pack_fifo(list("abcd"), 16, size_of=sizes.get,
                               skip_ahead=False)
    assert taken == ["a"] and rest == ["b", "c", "d"]


def test_pack_fifo_preserves_order():
    taken, rest, used = pack_fifo(list(range(10)), 4)
    assert taken == [0, 1, 2, 3] and rest == [4, 5, 6, 7, 8, 9]
    assert used == 4


# ---------------------------------------------------------------------------
# dynamic batcher on a virtual clock
# ---------------------------------------------------------------------------

class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(rid, k=1):
    return ServeRequest(rid=rid, seeds=np.arange(k, dtype=np.int64))


def test_size_trigger_fires_full_bucket():
    clk = Clock()
    b = DynamicBatcher(max_seeds=4, max_wait=1.0, clock=clk)
    for i in range(3):
        b.submit(_req(i))
    assert b.poll() is None                  # 3 < 4 and no deadline yet
    b.submit(_req(3))
    batch = b.poll()                         # size trigger, zero wait
    assert [r.rid for r in batch] == [0, 1, 2, 3]
    assert b.poll() is None


def test_deadline_trigger_fires_partial_batch():
    clk = Clock()
    b = DynamicBatcher(max_seeds=8, max_wait=0.5, clock=clk)
    b.submit(_req(0))
    clk.t = 0.4
    assert b.poll() is None                  # deadline not reached
    clk.t = 0.51
    batch = b.poll()
    assert [r.rid for r in batch] == [0]


def test_oversized_request_rejected():
    b = DynamicBatcher(max_seeds=4, max_wait=0.1, clock=Clock())
    with pytest.raises(ValueError):
        b.submit(_req(0, k=5))


def test_no_head_of_line_blocking():
    clk = Clock()
    b = DynamicBatcher(max_seeds=8, max_wait=0.5, clock=clk)
    b.submit(_req(0, k=6))
    b.submit(_req(1, k=5))                   # does not fit with rid 0
    b.submit(_req(2, k=2))                   # fits alongside rid 0
    clk.t = 0.6
    batch = b.poll()
    assert [r.rid for r in batch] == [0, 2]  # rid 1 skipped, not starved:
    clk.t = 1.2
    assert [r.rid for r in b.poll()] == [1]  # it leads the next batch


def test_property_random_trace_exactly_once_and_deadlines():
    """Seeded random arrival traces: every request leaves in exactly one
    batch, no batch exceeds capacity, and no request launches later than
    its deadline (ready time + max_wait) while the consumer polls."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        clk = Clock()
        max_seeds, max_wait = 16, 0.05
        b = DynamicBatcher(max_seeds, max_wait, clock=clk)
        n = 60
        arrivals = np.cumsum(rng.exponential(0.01, n))
        sizes = rng.integers(1, 5, n)
        served = {}
        i = 0
        t = 0.0
        while len(served) < n:
            # advance the clock in small ticks, submitting due arrivals
            while i < n and arrivals[i] <= t:
                b.submit(_req(i, int(sizes[i])))
                i += 1
            batch = b.poll()
            if batch:
                assert sum(r.n_seeds for r in batch) <= max_seeds
                for r in batch:
                    assert r.rid not in served, "served twice"
                    served[r.rid] = clk()
                    # poll cadence (2 ms) bounds the detection lag
                    assert clk() <= r.t_ready + max_wait + 0.002 + 1e-9
            t += 0.002
            clk.t = t
        assert len(served) == n
        assert b.poll() is None and len(b) == 0


def test_take_blocking_with_timeout_returns_none():
    b = DynamicBatcher(max_seeds=4, max_wait=10.0)
    assert b.take(timeout=0.01) is None


def test_take_blocking_deadline_wakeup():
    import time
    b = DynamicBatcher(max_seeds=100, max_wait=0.02)
    b.submit(_req(0))
    t0 = time.monotonic()
    batch = b.take(timeout=5.0)
    dt = time.monotonic() - t0
    assert batch and batch[0].rid == 0
    assert dt < 1.0                          # woke on the deadline, not the timeout


def test_flush_drains_everything():
    clk = Clock()
    b = DynamicBatcher(max_seeds=4, max_wait=100.0, clock=clk)
    for i in range(11):
        b.submit(_req(i))
    batches = b.flush()
    assert [len(x) for x in batches] == [4, 4, 3]
    assert sorted(r.rid for x in batches for r in x) == list(range(11))


# ---------------------------------------------------------------------------
# deadlines + exactly-once settlement (DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_reap_expired_removes_only_past_deadline():
    clk = Clock()
    b = DynamicBatcher(max_seeds=100, max_wait=10.0, clock=clk)
    early, late, none = _req(0), _req(1, k=2), _req(2)
    early.deadline, late.deadline = 0.5, 2.0
    for r in (early, late, none):
        b.submit(r)
    assert b.reap_expired(0.4) == []
    reaped = b.reap_expired(1.0)
    assert [r.rid for r in reaped] == [0]
    assert len(b) == 2 and b.n_expired == 1
    # remaining bookkeeping stays consistent: a full flush yields the rest
    assert sorted(r.rid for x in b.flush() for r in x) == [1, 2]


def test_reap_expired_is_noop_without_deadlines():
    b = DynamicBatcher(max_seeds=4, max_wait=10.0, clock=Clock())
    for i in range(3):
        b.submit(_req(i))
    assert b.reap_expired(1e9) == []          # O(1) fast path
    assert len(b) == 3 and b.n_expired == 0


def test_reaped_seeds_do_not_count_toward_size_trigger():
    clk = Clock()
    b = DynamicBatcher(max_seeds=4, max_wait=10.0, clock=clk)
    doomed = _req(0, k=3)
    doomed.deadline = 0.1
    b.submit(doomed)
    b.reap_expired(1.0)
    b.submit(_req(1, k=3))
    assert b.poll() is None                   # 3 < 4: reap fixed the sum
    b.submit(_req(2, k=1))
    assert [r.rid for r in b.poll()] == [1, 2]


def test_settlement_is_first_transition_wins():
    r = _req(0)
    assert r.finish(np.zeros((1, 2)), 1.0)
    assert not r.fail(RuntimeError("late failover duplicate"), 2.0)
    assert not r.finish(np.ones((1, 2)), 3.0)
    assert r.error is None and r.n_settles == 1
    assert r.t_done == 1.0 and (r.result == 0).all()

    f = _req(1)
    assert f.fail(ValueError("boom"), 1.0)
    assert not f.finish(np.zeros((1, 2)), 2.0)
    assert f.result is None and f.n_settles == 1
    assert f.wait_done(0)                     # settled: no blocking
    with pytest.raises(ValueError, match="boom"):
        f.wait()                              # the typed error, re-raised


def test_wait_raises_the_typed_error_object():
    from repro.serve.errors import DeadlineExceeded
    r = _req(0)
    err = DeadlineExceeded(0, deadline=1.0, now=2.0)
    r.fail(err, 2.0)
    with pytest.raises(DeadlineExceeded) as ei:
        r.wait()
    assert ei.value is err and ei.value.rid == 0
    assert isinstance(ei.value, TimeoutError)  # and shed/crash types differ
    assert isinstance(ei.value, RuntimeError)  # old call sites keep passing
