"""Static validation of the sharding strategy registry: every ARGUMENT
sharding divides its dimension on both production meshes, for all 40 cells —
the cheap host-side version of the dry-run's divisibility contract.

(Intermediate/activation shardings may pad unevenly; argument shardings in
jax.jit must divide exactly, which is what these tests pin.)
"""
import itertools

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.launch import sharding

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class FakeMesh:
    def __init__(self, multi_pod):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        self.shape = dict(zip(self.axis_names,
                              (2, 16, 16) if multi_pod else (16, 16)))


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _check_divisible(tree, pspecs, mesh, ctx):
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(specs), ctx
    for leaf, spec in zip(leaves, specs):
        for dim, entry in enumerate(spec):
            size = _axis_size(mesh, entry)
            assert leaf.shape[dim] % size == 0, \
                f"{ctx}: shape {leaf.shape} dim {dim} not divisible by {size}"


@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("arch_id", sorted(registry.ARCHS))
def test_param_and_input_shardings_divide(arch_id, multi_pod):
    from repro.launch.dryrun import param_tree_for
    mesh = FakeMesh(multi_pod)
    for shape_name in registry.shapes_for(arch_id):
        shape = registry.shapes_for(arch_id)[shape_name]
        cfg = registry.get_config(arch_id, shape=shape)
        specs, _ = registry.input_specs(arch_id, shape_name)
        params = param_tree_for(arch_id, cfg)
        p_pspec = sharding.param_pspecs(arch_id, params, mesh)
        in_pspec = sharding.input_pspecs(arch_id, shape, specs, mesh)
        _check_divisible(params, p_pspec, mesh,
                         f"{arch_id}/{shape_name}/params")
        _check_divisible(specs, in_pspec, mesh,
                         f"{arch_id}/{shape_name}/inputs")
