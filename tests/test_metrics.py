"""Streaming metrics plane + per-class SLO burn-rate engine (DESIGN.md §15).

Histogram/exposition/SLO logic is pure host code on virtual clocks — no
jax, no wall-clock flake.  The single jax-backed test at the bottom proves
the full wiring: a live ``ClusterServer`` under unreachable latency
targets must shed best_effort before any interactive request, and its
scraped ``/metrics`` exposition must agree with the engine's own summary.

Property tests run under real ``hypothesis`` when installed, else the
deterministic shim (``tests/_hypothesis_shim.py``).
"""
import math
import threading
import urllib.request

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback; requirements-dev.txt has the real one
    from _hypothesis_shim import given, settings, st

from repro.serve.metrics import (BUCKET_UPPERS, HIST_MIN, N_BUCKETS,
                                 LatencyHistogram, MetricsRegistry,
                                 bucket_index, bucket_lower, bucket_upper,
                                 histogram_counts_from_samples,
                                 parse_exposition, quantile_from_counts)
from repro.serve.slo import (CLASSES, DEFAULT_SLOS, SHED_ORDER, ClassSLO,
                             SLOEngine)
from repro.serve.telemetry import TelemetryHub

# latencies as integer microseconds, 1 µs .. ~16 s — spans the whole ladder
lat_us = st.integers(min_value=1, max_value=16_000_000)


# ---------------------------------------------------------------------------
# Bucket scheme
# ---------------------------------------------------------------------------

def test_bucket_bounds_partition_the_line():
    assert bucket_lower(0) == 0.0 and bucket_upper(0) == HIST_MIN
    for i in range(1, N_BUCKETS):
        assert bucket_upper(i - 1) == bucket_lower(i)
        assert bucket_upper(i) / bucket_upper(i - 1) == pytest.approx(
            math.sqrt(2.0))
    assert bucket_upper(N_BUCKETS) == math.inf


@settings(max_examples=200)
@given(lat_us)
def test_bucket_index_contains_its_value(us):
    v = us / 1e6
    i = bucket_index(v)
    assert bucket_lower(i) < v <= bucket_upper(i)


def test_bucket_index_exact_boundaries_land_inside():
    # v == upper must stay in bucket i ((lower, upper] is right-closed)
    for i in (0, 1, 7, N_BUCKETS - 1):
        assert bucket_index(BUCKET_UPPERS[i]) == i


# ---------------------------------------------------------------------------
# Mergeable histograms: per-lane merge bounds the true percentile
# ---------------------------------------------------------------------------

@settings(max_examples=60)
@given(st.lists(lat_us, min_size=1, max_size=120),
       st.integers(min_value=1, max_value=5))
def test_merged_lane_histograms_bound_true_percentiles(us_list, n_lanes):
    """Round-robin observations across per-lane histograms, merge, and the
    exact q-quantile order statistic must lie inside the merged histogram's
    reported bucket — the one-bucket exactness contract merging promises."""
    vals = [us / 1e6 for us in us_list]
    lanes = [LatencyHistogram() for _ in range(n_lanes)]
    for k, v in enumerate(vals):
        lanes[k % n_lanes].observe(v)
    merged = LatencyHistogram()
    for h in lanes:
        merged.merge(h)
    assert merged.count == len(vals)
    assert merged.sum == pytest.approx(sum(vals))
    ordered = sorted(vals)
    for q in (0.5, 0.95, 0.99):
        exact = ordered[min(max(math.ceil(q * len(vals)), 1),
                            len(vals)) - 1]
        lo, hi = merged.quantile_bounds(q)
        assert lo < exact <= hi, (q, exact, lo, hi)
        assert merged.quantile(q) == hi


def test_quantile_from_counts_empty_and_rank_clamp():
    assert quantile_from_counts([0] * (N_BUCKETS + 1), 0.99) == -1
    counts = [0] * (N_BUCKETS + 1)
    counts[5] = 1
    for q in (0.0, 0.5, 1.0):
        assert quantile_from_counts(counts, q) == 5


# ---------------------------------------------------------------------------
# Counters / gauges
# ---------------------------------------------------------------------------

@settings(max_examples=60)
@given(st.lists(st.integers(min_value=0, max_value=1000), min_size=1,
                max_size=50))
def test_counter_is_monotonic_across_increments(incs):
    reg = MetricsRegistry()
    c = reg.counter("events_total")
    seen = []
    for n in incs:
        c.inc(n, kind="x")
        seen.append(c.value(kind="x"))
    assert seen == sorted(seen)
    assert seen[-1] == sum(incs)


def test_counter_rejects_negative_increment():
    c = MetricsRegistry().counter("events_total")
    with pytest.raises(ValueError):
        c.inc(-1)


def test_family_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# Exposition round-trip
# ---------------------------------------------------------------------------

def _registry_with_everything():
    reg = MetricsRegistry()
    reg.counter("requests_total", "served requests").inc(
        7, outcome="served", **{"class": "interactive"})
    reg.gauge("queue", "batcher depth").set(3.5, lane="0")
    h = reg.histogram("request_latency_seconds", "e2e latency")
    for us in (90, 300, 300, 5000, 250_000):
        h.observe(us / 1e6, exemplar=f"rid-{us}", **{"class": "interactive"})
    return reg


@settings(max_examples=30)
@given(st.lists(lat_us, min_size=1, max_size=60))
def test_histogram_round_trips_through_exposition(us_list):
    """render → parse → rebuilt non-cumulative counts must equal the
    original bucket counts exactly (the ``le`` bounds re-parse to the
    shared float64 bounds)."""
    reg = MetricsRegistry()
    h = reg.histogram("request_latency_seconds")
    for us in us_list:
        h.observe(us / 1e6, **{"class": "batch"})
    fams = parse_exposition(reg.render())
    samples = fams["neurachip_request_latency_seconds"]["samples"]
    counts = histogram_counts_from_samples(samples, {"class": "batch"})
    assert counts == h.labeled(**{"class": "batch"}).counts
    for q in (0.5, 0.99):
        assert (quantile_from_counts(counts, q)
                == quantile_from_counts(
                    h.labeled(**{"class": "batch"}).counts, q))


def test_exposition_text_shape_and_values():
    reg = _registry_with_everything()
    text = reg.render()
    assert "# TYPE neurachip_requests_total counter" in text
    assert "# HELP neurachip_queue batcher depth" in text
    fams = parse_exposition(text)
    (_, labels, v, _), = fams["neurachip_requests_total"]["samples"]
    assert v == 7 and labels == {"outcome": "served",
                                 "class": "interactive"}
    assert fams["neurachip_queue"]["samples"][0][2] == 3.5
    hist = fams["neurachip_request_latency_seconds"]
    assert hist["type"] == "histogram"
    names = {n for n, _, _, _ in hist["samples"]}
    assert {"neurachip_request_latency_seconds_bucket",
            "neurachip_request_latency_seconds_sum",
            "neurachip_request_latency_seconds_count"} <= names
    count = [v for n, _, v, _ in hist["samples"] if n.endswith("_count")][0]
    assert count == 5
    # cumulative buckets are non-decreasing and end at the total count
    les = [(float("inf") if l["le"] == "+Inf" else float(l["le"]), v)
           for n, l, v, _ in hist["samples"] if n.endswith("_bucket")]
    vals = [v for _, v in sorted(les)]
    assert vals == sorted(vals) and vals[-1] == 5


def test_exemplars_survive_the_round_trip():
    reg = _registry_with_everything()
    fams = parse_exposition(reg.render())
    ex = {e for _, _, _, e in
          fams["neurachip_request_latency_seconds"]["samples"]
          if e is not None}
    ids = {trace_id for trace_id, _ in ex}
    assert "rid-90" in ids and "rid-250000" in ids
    # the exemplar value is the observed latency, inside its bucket
    for trace_id, v in ex:
        i = bucket_index(v)
        assert bucket_lower(i) < v <= bucket_upper(i)


# ---------------------------------------------------------------------------
# TelemetryHub feed
# ---------------------------------------------------------------------------

def test_connect_hub_refreshes_gauges_and_counter_totals():
    t = {"now": 0.0}
    hub = TelemetryHub(2, clock=lambda: t["now"])
    reg = MetricsRegistry()
    reg.connect_hub(hub)
    hub.register_probe("queue_depth", lambda: [3, 7])
    hub.count("served", 0, 4)
    hub.sample()
    lane = reg.gauge("lane")
    assert lane.value(lane="0", field="queue_depth") == 3.0
    assert lane.value(lane="1", field="queue_depth") == 7.0
    tot = reg.counter("telemetry_total")
    assert tot.value(lane="0", counter="served") == 4
    # totals stay monotonic across ticks as the hub counts up
    hub.count("served", 0, 2)
    hub.sample()
    assert tot.value(lane="0", counter="served") == 6


def test_render_is_thread_safe_under_concurrent_observes():
    reg = MetricsRegistry()
    h = reg.histogram("request_latency_seconds")
    stop = threading.Event()

    def pound():
        k = 0
        while not stop.is_set():
            h.observe((k % 1000 + 1) / 1e4, **{"class": "batch"})
            k += 1

    thread = threading.Thread(target=pound)
    thread.start()
    try:
        for _ in range(20):
            fams = parse_exposition(reg.render())
            samples = fams["neurachip_request_latency_seconds"]["samples"]
            counts = histogram_counts_from_samples(samples,
                                                   {"class": "batch"})
            cnt = [v for n, _, v, _ in samples if n.endswith("_count")]
            assert sum(counts) == int(cnt[0])
    finally:
        stop.set()
        thread.join()


# ---------------------------------------------------------------------------
# SLO burn-rate engine (virtual clock)
# ---------------------------------------------------------------------------

def _engine(**kw):
    t = {"now": 0.0}
    kw.setdefault("clock", lambda: t["now"])
    kw.setdefault("slos", [ClassSLO("interactive", 10.0, 0.01),
                           ClassSLO("batch", 10.0, 0.05),
                           ClassSLO("best_effort", 10.0, 0.20)])
    kw.setdefault("fast_window", 1.0)
    kw.setdefault("slow_window", 5.0)
    kw.setdefault("sustain_ticks", 2)
    kw.setdefault("recover_ticks", 3)
    return SLOEngine(**kw), t


def _burn_all(eng, t, seconds, n=10):
    for c in CLASSES:
        for _ in range(n):
            eng.observe(c, seconds)


def test_burn_rate_is_violation_fraction_over_budget():
    eng, t = _engine()
    for _ in range(8):
        eng.observe("batch", 0.001)        # under the 10 ms target
    for _ in range(2):
        eng.observe("batch", 0.5)          # over
    t["now"] = 0.5
    eng.tick()
    s = eng.summary()["batch"]
    # 2/10 violations over budget 0.05 → burn 4.0 on both windows
    assert s["burn_fast"] == pytest.approx(4.0)
    assert s["burn_slow"] == pytest.approx(4.0)
    assert s["n"] == 10 and s["violations"] == 2


def test_quiet_class_has_zero_burn():
    eng, t = _engine()
    t["now"] = 1.0
    eng.tick()
    assert all(s["burn_fast"] == 0.0 for s in eng.summary().values())


def test_shed_order_best_effort_first_then_batch_never_interactive():
    eng, t = _engine(sustain_ticks=2)
    evs = []
    for k in range(1, 7):
        _burn_all(eng, t, 0.5)             # everything violates
        t["now"] = 0.1 * k
        evs += eng.tick()
    # tick 2 sheds best_effort; the escalation needs a fresh sustain,
    # so batch sheds on tick 4
    assert [(e["cls"], e["on"]) for e in evs] == [
        ("best_effort", True), ("batch", True)]
    assert eng.shed_classes == frozenset(SHED_ORDER)
    assert not eng.should_shed("interactive")
    assert eng.should_shed("best_effort") and eng.should_shed("batch")
    for e in evs:
        assert e["burn_fast"] > eng.burn_threshold


def test_transient_spike_does_not_shed():
    """One hot tick under sustain_ticks=2 then quiet — no shed event."""
    eng, t = _engine(sustain_ticks=2)
    _burn_all(eng, t, 0.5)
    t["now"] = 0.1
    assert eng.tick() == []
    # fast window (1 s) slides past the burst; slow keeps it — not both hot
    for k in range(2, 6):
        t["now"] = k * 1.0
        assert eng.tick() == []
    assert eng.shed_classes == frozenset()


def test_recovery_unsheds_in_reverse_after_quiet_ticks():
    eng, t = _engine(sustain_ticks=1, recover_ticks=2)
    _burn_all(eng, t, 0.5)
    t["now"] = 0.1
    eng.tick()                             # sheds best_effort
    t["now"] = 0.2
    eng.tick()                             # escalates to batch
    assert eng.shed_classes == frozenset(SHED_ORDER)
    evs = []
    for k in range(1, 10):
        t["now"] = 10.0 + k                # windows empty: cool ticks
        evs += eng.tick()
        if not eng.shed_classes:
            break
    assert [(e["cls"], e["on"]) for e in evs] == [
        ("batch", False), ("best_effort", False)]


def test_engine_writes_burn_and_shed_gauges():
    reg = MetricsRegistry()
    eng, t = _engine(registry=reg, sustain_ticks=1)
    _burn_all(eng, t, 0.5)
    t["now"] = 0.1
    eng.tick()
    g = reg.gauge("slo_burn_rate")
    s = eng.summary()
    for c in CLASSES:
        assert g.value(**{"class": c, "window": "fast"}) == pytest.approx(
            s[c]["burn_fast"])
    assert reg.gauge("slo_shed").value(**{"class": "best_effort"}) == 1.0
    assert reg.gauge("slo_shed").value(**{"class": "interactive"}) == 0.0
    # observes flowed into the registry histogram too
    hist = reg.histogram("request_latency_seconds")
    assert hist.labeled(**{"class": "interactive"}).count == 10


def test_default_slos_cover_every_class_and_validate():
    assert tuple(s.name for s in DEFAULT_SLOS) == CLASSES
    with pytest.raises(ValueError):
        ClassSLO("premium", 10.0, 0.01)
    with pytest.raises(ValueError):
        ClassSLO("batch", 10.0, 0.0)
    with pytest.raises(ValueError):
        SLOEngine(slos=[ClassSLO("batch", 10.0, 0.1)])


# ---------------------------------------------------------------------------
# HTTP exposition endpoint
# ---------------------------------------------------------------------------

def test_metrics_server_serves_render_and_healthz():
    from repro.launch.metrics_server import MetricsServer
    reg = _registry_with_everything()
    srv = MetricsServer(reg.render, port=0)
    try:
        assert srv.port > 0
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.status == 200
            fams = parse_exposition(resp.read().decode())
        assert "neurachip_requests_total" in fams
        ex = [e for _, _, _, e in
              fams["neurachip_request_latency_seconds"]["samples"]
              if e is not None]
        assert ex, "exemplars must survive the HTTP round trip"
        health = srv.url.rsplit("/", 1)[0] + "/healthz"
        with urllib.request.urlopen(health, timeout=10) as resp:
            assert resp.read() == b"ok\n"
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Full wiring: live cluster sheds by class and exports truthfully
# ---------------------------------------------------------------------------

def test_cluster_slo_sheds_best_effort_before_interactive():
    """End-to-end: unreachable targets drive the burn over threshold; the
    admission arm must reject best_effort with a typed, class-carrying
    ``Overloaded`` while interactive keeps flowing, and the scraped
    exposition must agree with ``stats()['classes']`` (p99 within one
    bucket)."""
    import numpy as np

    from repro.launch.gnn_serve import build_world
    from repro.serve import ClusterServer, Overloaded

    cfg, params, indptr, indices, store = build_world("gcn", 256, 1024, 8,
                                                      seed=0)
    slos = [ClassSLO("interactive", 1.0, 0.01),
            ClassSLO("batch", 1.0, 0.05),
            ClassSLO("best_effort", 1.0, 0.20)]
    srv = ClusterServer("gcn", cfg, params, indptr, indices, store,
                        n_lanes=2, fanouts=(2, 2), backend="dense", seed=0,
                        telemetry_interval=0.02, slo=slos,
                        slo_fast_window=5.0, slo_slow_window=30.0,
                        slo_sustain_ticks=1, slo_recover_ticks=10**6,
                        metrics_port=0)
    rng = np.random.default_rng(1)
    shed = {"interactive": 0, "best_effort": 0}
    int_after_shed = 0
    with srv:
        srv.warmup()
        for _ in range(40):
            pend = []
            for cls in ("interactive", "best_effort"):
                try:
                    pend.append(srv.submit(rng.integers(0, 256, 2),
                                           cls=cls))
                    if cls == "interactive" and shed["best_effort"]:
                        int_after_shed += 1
                except Overloaded as e:
                    assert e.cls == cls
                    shed[cls] += 1
            for r in pend:
                r.wait_done(timeout=60)
            if shed["best_effort"] >= 3 and int_after_shed >= 3:
                break
        st_classes = srv.stats()["classes"]
        with urllib.request.urlopen(srv.stats()["metrics_url"],
                                    timeout=10) as resp:
            fams = parse_exposition(resp.read().decode())
        events = [e for e in srv.telemetry.events
                  if e.get("event") == "shed_class" and e.get("on")]
    assert shed["best_effort"] >= 3 and shed["interactive"] == 0
    assert int_after_shed >= 3
    assert events and events[0]["cls"] == "best_effort"
    assert st_classes["best_effort"]["shed"]
    assert not st_classes["interactive"]["shed"]
    hist = fams["neurachip_request_latency_seconds"]["samples"]
    for cls, s in st_classes.items():
        if not s["n"]:
            continue
        counts = histogram_counts_from_samples(hist, {"class": cls})
        scraped = quantile_from_counts(counts, 0.99)
        assert abs(scraped - bucket_index(s["p99_ms"] / 1e3)) <= 1
