"""Distributed SpMM (shard_map, 8 fake devices).

Runs DIRECTLY when the interpreter already has ≥8 devices (the CI
multi-device leg sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
process-wide); otherwise falls back to a subprocess so the flag never leaks
into other tests.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed
from repro.core.compat import shard_map, use_mesh

rng = np.random.default_rng(1)
n, e, d = 96, 700, 32
rows = rng.integers(0, n, e); cols = rng.integers(0, n, e)
vals = rng.normal(size=e).astype(np.float32)
x = rng.normal(size=(n, d)).astype(np.float32)
dense = np.zeros((n, n), np.float32); np.add.at(dense, (rows, cols), vals)
ref = dense @ x

mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = distributed.plan_distributed_spmm(rows, cols, vals, n, n_shards=4,
                                         ring=True)
xp = distributed.permute_features(x, plan)

f = distributed.make_allgather_spmm(mesh, plan)
with use_mesh(mesh):
    y = f(jnp.asarray(xp), jnp.asarray(plan.rows_local),
          jnp.asarray(plan.cols_perm), jnp.asarray(plan.vals))
err = abs(distributed.unpermute_features(np.asarray(y), plan, n) - ref).max()
assert err < 1e-4, f"allgather spmm err {err}"

g = distributed.make_ring_spmm(mesh, plan)
with use_mesh(mesh):
    y2 = g(jnp.asarray(xp), jnp.asarray(plan.ring_rows),
           jnp.asarray(plan.ring_cols), jnp.asarray(plan.ring_vals))
err2 = abs(distributed.unpermute_features(np.asarray(y2), plan, n) - ref).max()
assert err2 < 1e-4, f"ring spmm err {err2}"

# gradients agree between the two schedules
def loss_ag(xp_):
    return jnp.sum(f(xp_, jnp.asarray(plan.rows_local),
                     jnp.asarray(plan.cols_perm), jnp.asarray(plan.vals))**2)
def loss_ring(xp_):
    return jnp.sum(g(xp_, jnp.asarray(plan.ring_rows),
                     jnp.asarray(plan.ring_cols),
                     jnp.asarray(plan.ring_vals))**2)
with use_mesh(mesh):
    g1 = jax.grad(loss_ag)(jnp.asarray(xp))
    g2 = jax.grad(loss_ring)(jnp.asarray(xp))
gerr = float(jnp.abs(g1 - g2).max())
assert gerr < 1e-3, f"grad mismatch {gerr}"

# exact per-shard balance (DRHM bijection)
assert plan.rows_local.size == plan.n_shards * plan.edges_per_shard

# compressed psum matches plain psum within int8 tolerance
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum
def ps(z):
    return jax.lax.psum(z, "data")
def cps(z):
    return compressed_psum(z, "data")
z = rng.normal(size=(8, 64)).astype(np.float32)
sm_ps = shard_map(ps, mesh=mesh, in_specs=P("data"), out_specs=P())
sm_cps = shard_map(cps, mesh=mesh, in_specs=P("data"), out_specs=P())
with use_mesh(mesh):
    a = sm_ps(jnp.asarray(z))
    b = sm_cps(jnp.asarray(z))
cerr = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
assert cerr < 0.05, f"compressed psum rel err {cerr}"
print("DISTRIBUTED_OK")
"""

SCRIPT = ('import os\n'
          'os.environ["XLA_FLAGS"] = '
          '"--xla_force_host_platform_device_count=8"\n' + BODY)


def test_distributed_spmm_direct():
    """The multi-device CI leg exercises the distributed executor in-process
    (no subprocess indirection)."""
    import jax
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (CI multi-device leg)")
    exec(compile(BODY, "<distributed-checks>", "exec"), {})


def test_distributed_spmm_subprocess():
    import jax
    if jax.device_count() >= 8:
        pytest.skip("direct multi-device test covers this")
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        # JAX_PLATFORMS must survive into the child: without it jax may
        # probe accelerator backends (e.g. a baked-in libtpu) and hang for
        # minutes on metadata timeouts
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_OK" in proc.stdout
